import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

This proves the distribution config is coherent without hardware: a sharding
mismatch, compile-time OOM, or unsupported collective is a bug. For each
combination we record ``memory_analysis()`` (fits-in-HBM proof),
``cost_analysis()`` (FLOPs/bytes) and the parsed collective schedule — the
inputs to EXPERIMENTS.md §Dry-run and §Roofline.

Cost correction: XLA's cost analysis counts a ``while`` (lax.scan) body ONCE
regardless of trip count, so scanned deep stacks under-report FLOPs/bytes/
collectives. The fit-proof compile uses the real scanned program; the cost
numbers come from two shallow *unrolled* compiles (depth P and 2P at full
width/batch/mesh) extrapolated linearly in depth:
    cost(L) = base + L * per_layer.

Usage:
    python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --multi-pod both
    python -m repro.launch.dryrun ... --agg hierarchical_trim   # paper mode

Inputs are ShapeDtypeStructs (jax.eval_shape) — nothing is allocated.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.memory_model import serve_memory_gb, train_memory_gb
from repro.analysis.roofline import model_flops, parse_collectives, roofline_terms
from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.configs.base import ArchConfig, InputShape
from repro.data.pipeline import make_batch_specs
from repro.distributed.aggregation import AggregatorConfig
from repro.distributed.sharding import (
    batch_axes, cache_specs, param_specs,
)
from repro.distributed.trainer import (
    TrainConfig, make_train_step, _batch_spec_tree,
)
from repro.launch.compat import set_mesh
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init

# FSDP for models whose optimizer state cannot replicate across data workers
FSDP_THRESHOLD = 2e9
# weight-gathered serving: above this size, params shard over (data, model)
# and GSPMD all-gathers weights per layer (16-way TP alone cannot hold them)
SERVE_GATHER_THRESHOLD = 50e9
# sliding window used for the long_500k serve variant of full-attention archs
LONG_WINDOW = 4096
# target tokens per device per micro-batch (activation-memory knob)
MICRO_TOKENS = 4096


def pick_remat_group(L: int) -> int:
    """Largest divisor of L bounded by ~L/12: saved-residual count stays
    small while the recompute window stays shallow."""
    cap = max(2, L // 12)
    best = 1
    for g in range(2, cap + 1):
        if L % g == 0:
            best = g
    return best


def pick_n_micro(cfg: ArchConfig, shape: InputShape, mesh) -> int:
    data_shards = mesh.shape["data"] * dict(mesh.shape).get("pod", 1)
    b_dev = max(shape.global_batch // data_shards, 1)
    tok_dev = b_dev * shape.seq_len
    n = max(1, min(tok_dev // MICRO_TOKENS, b_dev))
    while b_dev % n:
        n -= 1
    return n


def serve_cfg_for(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """long_500k needs sub-quadratic attention: full-attention archs switch
    to their sliding-window serve variant (same params, windowed mixer)."""
    if shape.name == "long_500k" and any(
        k == "attn" for k in cfg.block_pattern
    ):
        pat = tuple("swa" if k == "attn" else k for k in cfg.block_pattern)
        return dataclasses.replace(cfg, block_pattern=pat,
                                   window=cfg.window or LONG_WINDOW)
    return cfg


def batch_struct(cfg: ArchConfig, shape: InputShape):
    """ShapeDtypeStruct inputs for a train/prefill batch of arch x shape."""
    S, B = shape.seq_len, shape.global_batch
    toks = S
    extra = {}
    if cfg.family == "vlm":
        toks = S - cfg.n_patches
        extra["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, 1024), jnp.bfloat16
        )
    if cfg.family == "audio":
        extra["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frames, cfg.d_model), jnp.bfloat16
        )
    base = make_batch_specs(toks, B, cfg.vocab)
    return {**base, **extra}


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of (arch, shape) —
    weak-type-correct, shardable, no allocation."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.kind in ("train", "prefill"):
        return batch_struct(serve_cfg_for(cfg, shape), shape)
    return {"token": jax.ShapeDtypeStruct(
        (shape.global_batch, 1), jnp.int32)}


def _sharded(specs_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def decode_cache_len(cfg: ArchConfig, shape: InputShape) -> int:
    if shape.name == "long_500k":
        return cfg.window or LONG_WINDOW
    return min(shape.seq_len, 32768)


def build_lowered(cfg: ArchConfig, shape: InputShape, mesh, agg: str,
                  fsdp: bool, n_micro: int | None = None,
                  opt_dtype: str = "float32", comm_dtype: str = "float32",
                  gossip_rounds: int = 8):
    """Lower one step function for this cfg (possibly depth-reduced)."""
    params_struct = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg)
    )

    if shape.kind == "train":
        tc = TrainConfig(
            arch=cfg,
            agg=AggregatorConfig(kind=agg, F=1, gossip_rounds=gossip_rounds,
                                 gamma_period=4, drop_prob=0.1,
                                 comm_dtype=comm_dtype),
            opt=AdamWConfig(moment_dtype=opt_dtype),
            fsdp=fsdp,
            n_micro=n_micro if n_micro is not None
            else pick_n_micro(cfg, shape, mesh),
        )
        batch = batch_struct(cfg, shape)
        if agg == "mean":
            factory, shard_fn = make_train_step(tc, mesh)
            step_fn = factory(params_struct, tuple(batch))
            pspecs, ospecs, _ = shard_fn(params_struct, tuple(batch))
            opt_struct = jax.eval_shape(
                lambda p: adamw_init(p, opt_dtype), params_struct
            )
            with set_mesh(mesh):
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(
                        _sharded(pspecs, mesh), _sharded(ospecs, mesh),
                        _sharded(_batch_spec_tree(mesh, tuple(batch)), mesh),
                    ),
                )
                return jitted.lower(params_struct, opt_struct, batch)
        # decentralized robust step: worker-axis params
        from repro.distributed.trainer import (
            replicate_for_workers, worker_opt_init,
        )
        W = mesh.shape["data"] * dict(mesh.shape).get("pod", 1)
        pw_struct = jax.eval_shape(
            lambda p: replicate_for_workers(p, W), params_struct
        )
        ow_struct = jax.eval_shape(worker_opt_init, pw_struct)
        factory, shard_fn = make_train_step(tc, mesh)
        step_fn = factory(pw_struct, tuple(batch))
        pspecs, ospecs, bspec = shard_fn(pw_struct, tuple(batch))
        key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
        with set_mesh(mesh):
            jitted = jax.jit(
                step_fn,
                in_shardings=(
                    _sharded(pspecs, mesh), _sharded(ospecs, mesh),
                    _sharded(bspec, mesh), NamedSharding(mesh, P()),
                ),
            )
            return jitted.lower(pw_struct, ow_struct, batch, key_struct)

    serve_gather = cfg.param_count() > SERVE_GATHER_THRESHOLD
    pspecs = param_specs(params_struct, cfg, mesh, fsdp=serve_gather)
    B = shape.global_batch

    if shape.kind == "prefill":
        batch = batch_struct(cfg, shape)

        def prefill_step(params, batch):
            return M.prefill(
                params, cfg, batch["tokens"],
                patch_embeds=batch.get("patch_embeds"),
                frames=batch.get("frames"),
            )

        with set_mesh(mesh):
            jitted = jax.jit(
                prefill_step,
                in_shardings=(
                    _sharded(pspecs, mesh),
                    _sharded(_batch_spec_tree(mesh, tuple(batch)), mesh),
                ),
            )
            return jitted.lower(params_struct, batch)

    # decode
    cache_len = decode_cache_len(cfg, shape)
    if cfg.encoder_layers:
        enc_struct = jax.ShapeDtypeStruct(
            (B, cfg.n_frames, cfg.d_model), jnp.bfloat16
        )
        cache_struct = jax.eval_shape(
            lambda p, e: M.init_cache(p, cfg, B, cache_len, e),
            params_struct, enc_struct,
        )
    else:
        cache_struct = jax.eval_shape(
            lambda p: M.init_cache(p, cfg, B, cache_len), params_struct
        )
    cspecs = cache_specs(cache_struct, cfg, mesh)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)

    def decode_fn(params, cache, token):
        return M.decode_step(params, cfg, cache, token)

    from repro.distributed.sharding import fit_spec
    tok_spec = fit_spec(P(batch_axes(mesh), None), (B, 1), mesh)
    with set_mesh(mesh):
        jitted = jax.jit(
            decode_fn,
            in_shardings=(
                _sharded(pspecs, mesh), _sharded(cspecs, mesh),
                NamedSharding(mesh, tok_spec),
            ),
        )
        return jitted.lower(params_struct, cache_struct, token)


def _extract_costs(compiled, n_dev):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x returns [dict]
        cost = cost[0] if cost else {}
    coll = parse_collectives(compiled.as_text(), n_dev)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": float(coll["wire_bytes_per_device"]),
        "by_kind": coll["bytes_by_kind"],
        "counts": coll["count_by_kind"],
    }


def extrapolated_costs(cfg: ArchConfig, shape: InputShape, mesh, agg, fsdp,
                       opt_dtype: str = "float32",
                       comm_dtype: str = "float32", gossip_rounds: int = 8):
    """Depth-linear cost model from two shallow unrolled compiles."""
    n_dev = mesh.size
    Pn = len(cfg.block_pattern)
    L1, L2 = Pn, 2 * Pn
    if cfg.n_layers <= L2 and not cfg.scan_layers:
        return None  # direct costs are exact (fully unrolled program)
    costs = []
    for Lx in (L1, L2):
        # Costing variant removes every cost-hiding loop: layers unrolled,
        # n_micro=1 (micro scan), naive attention (the flash path's q/kv
        # chunk loops are while bodies XLA counts once). Identical math.
        c = dataclasses.replace(cfg, n_layers=Lx, scan_layers=False,
                                attn_impl="naive")
        lowered = build_lowered(c, shape, mesh, agg, fsdp, n_micro=1,
                                opt_dtype=opt_dtype, comm_dtype=comm_dtype,
                                gossip_rounds=gossip_rounds)
        costs.append(_extract_costs(lowered.compile(), n_dev))
    per_layer = {
        k: (costs[1][k] - costs[0][k]) / (L2 - L1)
        for k in ("flops", "bytes", "wire")
    }
    base = {k: costs[0][k] - L1 * per_layer[k] for k in per_layer}
    L = cfg.n_layers
    out = {k: max(base[k] + L * per_layer[k], 0.0) for k in per_layer}
    out["by_kind"] = {
        kind: max(
            costs[0]["by_kind"][kind]
            + (costs[1]["by_kind"][kind] - costs[0]["by_kind"][kind])
            / (L2 - L1) * (L - L1),
            0.0,
        )
        for kind in costs[0]["by_kind"]
    }
    out["counts"] = {
        kind: int(
            costs[0]["counts"][kind]
            + (costs[1]["counts"][kind] - costs[0]["counts"][kind])
            / (L2 - L1) * (L - L1)
        )
        for kind in costs[0]["counts"]
    }
    return out


def lower_one(arch: str, shape_name: str, multi_pod: bool, agg: str = "mean",
              skip_cost: bool = False, overrides: dict | None = None,
              opt_dtype: str = "float32", comm_dtype: str = "float32",
              gossip_rounds: int = 8):
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    cfg0 = get_config(arch)
    if overrides:
        cfg0 = dataclasses.replace(cfg0, **overrides)
    shape = INPUT_SHAPES[shape_name]
    cfg = serve_cfg_for(cfg0, shape) if shape.kind != "train" else cfg0
    fsdp = cfg.param_count() > FSDP_THRESHOLD and agg == "mean"

    # 1) the real program: proves compile + fit
    t0 = time.time()
    lowered = build_lowered(cfg, shape, mesh, agg, fsdp, opt_dtype=opt_dtype,
                            comm_dtype=comm_dtype,
                            gossip_rounds=gossip_rounds)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    ma = compiled.memory_analysis()

    # 2) depth-corrected costs
    direct = _extract_costs(compiled, n_dev)
    extr = None if skip_cost else extrapolated_costs(cfg, shape, mesh, agg,
                                                     fsdp, opt_dtype,
                                                     comm_dtype, gossip_rounds)
    use = extr if extr is not None else direct
    cost = {"flops": use["flops"], "bytes accessed": use["bytes"]}
    coll = {"wire_bytes_per_device": use["wire"],
            "bytes_by_kind": use["by_kind"], "count_by_kind": use["counts"]}
    mf = model_flops(cfg, shape)
    terms = roofline_terms(cost, coll, n_dev, mf)

    peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    mesh_shape = dict(mesh.shape)
    if shape.kind == "train":
        analytic = train_memory_gb(
            cfg, shape, mesh_shape, fsdp,
            pick_n_micro(cfg, shape, mesh),
            worker_axis=(agg != "mean"),
            moment_bytes=2 if opt_dtype == "bfloat16" else 4,
        )
    else:
        analytic = serve_memory_gb(
            cfg, shape, mesh_shape,
            decode_cache_len(cfg, shape) if shape.kind == "decode"
            else shape.seq_len,
            weight_gathered=cfg.param_count() > SERVE_GATHER_THRESHOLD,
        )
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "agg": agg,
        "ok": True,
        "fsdp": fsdp,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "xla_peak_gb_cpu_backend": round(peak / 1e9, 3),
        },
        "analytic_memory": analytic,
        "roofline": terms,
        "collectives": coll,
        "cost_mode": "extrapolated" if extr is not None else "direct",
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--agg", default="mean")
    ap.add_argument("--skip-cost", action="store_true",
                    help="fit-proof only (skip the costing compiles)")
    ap.add_argument("--moe-impl", default=None, choices=[None, "gspmd",
                                                         "sharded"])
    ap.add_argument("--remat-group", type=int, default=None)
    ap.add_argument("--pad-heads", type=int, default=None)
    ap.add_argument("--ce-chunk", type=int, default=None)
    ap.add_argument("--opt-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--comm-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--gossip-rounds", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    overrides = {}
    if args.moe_impl:
        overrides["moe_impl"] = args.moe_impl
    if args.remat_group:
        overrides["remat_group"] = args.remat_group
    if args.pad_heads:
        overrides["pad_heads_to"] = args.pad_heads
    if args.ce_chunk:
        overrides["ce_chunk"] = args.ce_chunk

    archs = [a for a in ARCH_IDS if a != "paper_sim"] \
        if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod
    ]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = lower_one(arch, shape, mp, args.agg,
                                    skip_cost=args.skip_cost,
                                    overrides=overrides or None,
                                    opt_dtype=args.opt_dtype,
                                    comm_dtype=args.comm_dtype,
                                    gossip_rounds=args.gossip_rounds)
                    r = rec["roofline"]
                    print(
                        f"OK   {tag}: compile={rec['compile_s']}s "
                        f"mem={rec['analytic_memory']['total_gb']}GB "
                        f"fits={rec['analytic_memory']['fits_16gb']} "
                        f"compute={r['compute_s']:.4f}s "
                        f"memory={r['memory_s']:.4f}s "
                        f"coll={r['collective_s']:.4f}s "
                        f"dom={r['dominant']} useful={r['useful_flop_ratio']:.2f}",
                        flush=True,
                    )
                except Exception as e:
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "agg": args.agg, "ok": False,
                        "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                    print(f"FAIL {tag}: {rec['error']}", flush=True)
                results.append(rec)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_ok = sum(r["ok"] for r in results)
    print(f"{n_ok}/{len(results)} combinations lowered+compiled")


if __name__ == "__main__":
    main()
