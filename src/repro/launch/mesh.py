"""Production mesh construction.

IMPORTANT: functions, not module-level constants — importing this module must
never touch jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init;
everything else must see the real device count).

Meshes are built via :func:`repro.launch.compat.make_mesh`, which requests
``AxisType.Auto`` axes on modern jax and silently drops the kwarg on jax
0.4.x (where all mesh axes are implicitly auto) — see
:mod:`repro.launch.compat` for the full compatibility story.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.launch.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """v5e production mesh: 16x16 single pod, or 2 pods x 16 x 16."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int | None = None) -> Mesh:
    """A mesh over whatever devices actually exist (tests / examples)."""
    n = jax.device_count()
    mp = model_parallel or 1
    assert n % mp == 0
    return make_mesh((n // mp, mp), ("data", "model"))
