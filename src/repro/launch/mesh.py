"""Production mesh construction.

IMPORTANT: functions, not module-level constants — importing this module must
never touch jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init;
everything else must see the real device count).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """v5e production mesh: 16x16 single pod, or 2 pods x 16 x 16."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(model_parallel: int | None = None) -> Mesh:
    """A mesh over whatever devices actually exist (tests / examples)."""
    n = jax.device_count()
    mp = model_parallel or 1
    assert n % mp == 0
    return jax.make_mesh(
        (n // mp, mp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
