"""jax version-compatibility shims.

The repo targets the modern (jax >= 0.6) sharding surface — explicit-axis
meshes (``jax.sharding.AxisType``), top-level ``jax.shard_map`` with
``axis_names=``/``check_vma=``, the ``jax.set_mesh`` ambient-mesh context,
and ``jax.lax.axis_size`` — but must also run on jax 0.4.x (the pinned
container toolchain), where none of those exist:

==================  =============================  ==========================
modern jax          jax 0.4.x                      shim behaviour
==================  =============================  ==========================
AxisType meshes     no ``axis_types=`` kwarg       drop the kwarg (0.4.x
                                                   meshes are implicitly
                                                   fully Auto)
jax.shard_map       jax.experimental.shard_map     ``axis_names`` -> ``auto``
  (axis_names=,       (auto=, check_rep=)            complement; ``check_vma``
   check_vma=)                                       -> ``check_rep``
jax.set_mesh        ``with mesh:`` resource env    return the Mesh itself
                                                   (it is a context manager)
jax.lax.axis_size   n/a                            ``psum(1, name)`` (static)
==================  =============================  ==========================

Import these helpers instead of touching ``jax.shard_map`` / ``jax.set_mesh``
/ ``jax.make_mesh(axis_types=...)`` directly anywhere in src/ or tests/.
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import Mesh

__all__ = ["HAS_AXIS_TYPE", "make_mesh", "shard_map", "set_mesh",
           "axis_size", "get_abstract_mesh"]

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices=None,
    axis_types=None,
) -> Mesh:
    """``jax.make_mesh`` that works on both jax 0.4.x and >= 0.6.

    On modern jax every axis defaults to ``AxisType.Auto`` (matching 0.4.x
    semantics, where all mesh axes are implicitly auto); on 0.4.x the
    ``axis_types`` kwarg does not exist and is dropped.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPE:
        if axis_types is None:
            axis_types = (jax.sharding.AxisType.Auto,) * len(tuple(axis_names))
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """``jax.shard_map`` with the modern keyword surface on any jax.

    ``axis_names`` is the set of *manual* axes (modern convention);
    ``check_vma`` maps to the legacy ``check_rep``.

    On 0.4.x the partial-auto mode (``auto=`` complement) is NOT used even
    when ``axis_names`` is a strict subset of the mesh axes: the era's XLA
    SPMD partitioner rejects programs mixing manual subgroups with auto
    regions (``PartitionId instruction is not supported`` aborts on
    ``axis_index``; hard CHECK-failures on collectives over constants).
    Instead the body runs fully manual over ALL mesh axes — semantics are
    unchanged (dims whose spec omits an auto axis are simply replicated into
    every shard), only the intra-body GSPMD tensor parallelism is lost on
    the legacy toolchain.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=frozenset())


def set_mesh(mesh: Mesh):
    """Ambient-mesh context: ``jax.set_mesh`` on modern jax, the Mesh's own
    resource-env context manager on 0.4.x."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def axis_size(name) -> int:
    """``jax.lax.axis_size`` fallback: ``psum`` of a literal 1 is evaluated
    statically to the axis size on every jax version."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def get_abstract_mesh():
    """The ambient mesh set by :func:`set_mesh`, or ``None`` when unset.

    Modern jax exposes ``jax.sharding.get_abstract_mesh``; on 0.4.x the
    ambient context is the Mesh resource env entered by ``with mesh:``.
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources

    mesh = thread_resources.env.physical_mesh
    return None if mesh.empty else mesh
