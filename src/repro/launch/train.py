"""Training launcher.

Runs real steps on the host's devices (tests/examples use reduced configs on
CPU; the same entry point drives TPU slices). The paper's robust aggregation
modes are first-class:

    python -m repro.launch.train --arch paper_sim --steps 100 \
        --agg hierarchical_trim --byzantine 2,5 --model-parallel 2

For the production 512-chip meshes, use this module from a TPU pod launcher;
on this CPU container, the multi-device path is exercised via
``--fake-devices N`` (set before jax init).
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_sim")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale variant of the arch")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--agg", default="mean",
                    choices=["mean", "pushsum", "pushsum_sparse",
                             "trimmed_mean", "hierarchical_trim"])
    ap.add_argument("--byzantine", default="",
                    help="comma-separated compromised worker indices")
    ap.add_argument("--trim-f", type=int, default=1)
    ap.add_argument("--gossip-rounds", type=int, default=16)
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--drop-prob", type=float, default=0.1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.fake_devices:
        import os
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices}"
        )

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.data import SyntheticLMData
    from repro.distributed.aggregation import AggregatorConfig
    from repro.distributed.trainer import (
        TrainConfig, make_train_step, param_spread,
        replicate_for_workers, worker_opt_init,
    )
    from repro.launch.compat import set_mesh
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.optim import AdamWConfig, adamw_init
    from repro.checkpoint import save_checkpoint

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_host_mesh(model_parallel=args.model_parallel)
    n_workers = mesh.shape["data"]
    byz = tuple(int(b) for b in args.byzantine.split(",") if b)

    tc = TrainConfig(
        arch=cfg,
        agg=AggregatorConfig(
            kind=args.agg, F=args.trim_f, gossip_rounds=args.gossip_rounds,
            gamma_period=args.gamma, drop_prob=args.drop_prob,
        ),
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                        total_steps=args.steps),
        n_micro=args.n_micro,
        byzantine_workers=byz,
        seed=args.seed,
    )
    data = SyntheticLMData(
        cfg.vocab, args.seq_len, args.global_batch, flavour="markov",
        n_agents=n_workers, seed=args.seed,
    )
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(key, cfg)

    factory, _ = make_train_step(tc, mesh)
    robust = args.agg != "mean"
    with set_mesh(mesh):
        if robust:
            params_w = replicate_for_workers(params, n_workers)
            opt_w = worker_opt_init(params_w)
            step = jax.jit(factory(params_w))
            spread_fn = jax.jit(param_spread)
            for s in range(args.steps):
                batch = data.batch(s)
                params_w, opt_w, loss = step(
                    params_w, opt_w, batch, jax.random.fold_in(key, s)
                )
                if s % max(args.steps // 10, 1) == 0 or s == args.steps - 1:
                    spread = float(spread_fn(params_w))
                    print(f"step {s:5d} loss {float(loss):.4f} "
                          f"consensus_spread {spread:.3e}", flush=True)
                if args.ckpt_dir and args.ckpt_every and \
                        (s + 1) % args.ckpt_every == 0:
                    save_checkpoint(args.ckpt_dir, s + 1, params_w)
        else:
            opt = adamw_init(params)
            step = jax.jit(factory(params))
            for s in range(args.steps):
                batch = data.batch(s)
                params, opt, loss = step(params, opt, batch)
                if s % max(args.steps // 10, 1) == 0 or s == args.steps - 1:
                    print(f"step {s:5d} loss {float(loss):.4f}", flush=True)
                if args.ckpt_dir and args.ckpt_every and \
                        (s + 1) % args.ckpt_every == 0:
                    save_checkpoint(args.ckpt_dir, s + 1, params)
    print("done")


if __name__ == "__main__":
    main()
