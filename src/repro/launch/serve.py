"""Serving launcher: batched prefill + decode loop on host devices.

    python -m repro.launch.serve --arch qwen3_8b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_sim")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.launch.compat import set_mesh
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_host_mesh(model_parallel=args.model_parallel)
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(key, cfg)

    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kwargs = {}
    if cfg.family == "audio":
        kwargs["frames"] = jax.random.normal(
            key, (B, cfg.n_frames, cfg.d_model), dtype=jnp.float32
        )
    if cfg.family == "vlm":
        kwargs["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_patches, 1024), dtype=jnp.float32
        )

    cache_len = S + args.gen + (cfg.n_patches if cfg.family == "vlm" else 0) + 1

    with set_mesh(mesh):
        logits, cache = M.prefill(
            params, cfg, prompts, cache_len=cache_len, **kwargs
        )
        decode = jax.jit(
            lambda p, c, t: M.decode_step(p, cfg, c, t)
        )
        tok = logits[:, -1].argmax(-1)[:, None].astype(jnp.int32)
        out = [tok]
        for i in range(args.gen - 1):
            logits, cache = decode(params, cache, tok)
            if args.temperature > 0:
                k = jax.random.fold_in(key, i)
                tok = jax.random.categorical(
                    k, logits[:, -1] / args.temperature
                )[:, None].astype(jnp.int32)
            else:
                tok = logits[:, -1].argmax(-1)[:, None].astype(jnp.int32)
            out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    print("generated token ids:")
    for row in gen:
        print("  ", list(map(int, row)))
    print("done")


if __name__ == "__main__":
    main()
