"""repro — fault-tolerant & Byzantine-resilient hierarchical non-Bayesian
learning (Mclaughlin/Ding/Edogmus/Su 2023) as a multi-pod JAX framework.

Subpackages: ``core`` (the paper), ``models``/``configs`` (assigned
architectures), ``distributed`` (robust aggregation + trainer/server),
``kernels`` (Pallas TPU), ``optim``/``data``/``checkpoint`` (substrate),
``launch`` (mesh/dryrun/train/serve), ``analysis`` (roofline/memory).
"""

__version__ = "1.0.0"
