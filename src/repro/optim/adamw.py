"""AdamW + cosine schedule + global-norm clipping, pure JAX.

Moments are stored in float32 regardless of param dtype (bf16 params with
f32 state is the production norm). State shards identically to its param
(see ``repro.distributed.sharding.opt_state_specs``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"   # "bfloat16" halves optimizer residency
                                    # (±0.1% step noise; §Perf iteration)


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params: Params, moment_dtype: str = "float32") -> Params:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    cfg: AdamWConfig, grads: Params, state: Params, params: Params
) -> tuple[Params, Params]:
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) * scale, grads
    )

    b1, b2 = cfg.beta1, cfg.beta2
    mdt = jnp.dtype(cfg.moment_dtype)
    m = jax.tree_util.tree_map(
        lambda mm, g: (b1 * mm.astype(jnp.float32) + (1 - b1) * g).astype(mdt),
        state["m"], grads,
    )
    v = jax.tree_util.tree_map(
        lambda vv, g: (b2 * vv.astype(jnp.float32) + (1 - b2) * g * g).astype(
            mdt
        ),
        state["v"], grads,
    )
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - b1**sf
    bc2 = 1.0 - b2**sf

    def upd(p, mm, vv):
        mhat = mm.astype(jnp.float32) / bc1
        vhat = vv.astype(jnp.float32) / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr * (delta + decay)).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}
