"""Serving example: batched prefill + decode for any assigned architecture
(reduced scale on CPU), exercising the same code path the decode_32k /
long_500k dry-runs lower.

Run:  PYTHONPATH=src python examples/serve_robust.py --arch rwkv6_1b6
      PYTHONPATH=src python examples/serve_robust.py --arch qwen3_8b
      PYTHONPATH=src python examples/serve_robust.py --arch whisper_small
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import model as M

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="rwkv6_1b6")
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--prompt-len", type=int, default=24)
ap.add_argument("--gen", type=int, default=12)
args = ap.parse_args()

cfg = reduced(get_config(args.arch))
key = jax.random.PRNGKey(0)
params = M.init_params(key, cfg)
B, S = args.batch, args.prompt_len

prompts = jax.random.randint(key, (B, S), 0, cfg.vocab)
kwargs = {}
if cfg.family == "audio":
    kwargs["frames"] = jax.random.normal(
        key, (B, cfg.n_frames, cfg.d_model), dtype=jnp.float32)
if cfg.family == "vlm":
    kwargs["patch_embeds"] = jax.random.normal(
        key, (B, cfg.n_patches, 1024), dtype=jnp.float32)

cache_len = S + args.gen + 1 + (cfg.n_patches if cfg.family == "vlm" else 0)
logits, cache = M.prefill(params, cfg, prompts, cache_len=cache_len, **kwargs)
decode = jax.jit(lambda p, c, t: M.decode_step(p, cfg, c, t))

tok = logits[:, -1].argmax(-1)[:, None].astype(jnp.int32)
out = [tok]
for _ in range(args.gen - 1):
    logits, cache = decode(params, cache, tok)
    tok = logits[:, -1].argmax(-1)[:, None].astype(jnp.int32)
    out.append(tok)
gen = jnp.concatenate(out, axis=1)

state_bytes = sum(
    l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(cache)
)
print(f"arch={cfg.name} family={cfg.family} "
      f"cache/state={state_bytes / 1e6:.2f} MB")
print("generated token ids:")
for row in gen:
    print("  ", list(map(int, row)))
print("serve_robust OK")
