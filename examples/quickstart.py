"""Quickstart: the paper's full pipeline in ~80 lines.

1. Build a hierarchical multi-agent system (M sub-networks + PS).
2. Run Algorithm 3 (packet-drop-tolerant non-Bayesian learning): every agent
   identifies theta* despite 30% packet loss and sparse PS fusion.
3. Run Algorithm 2 (Byzantine-resilient learning): F=2 compromised agents
   send calibrated lies; every normal agent still learns theta*.
4. Sweep 32 consensus scenarios (topology draws x drop rates x seeds) in ONE
   jitted vmapped scan over the sparse edge-list push-sum core.
5. Hierarchical consensus grid: a (topology x M x Gamma x drop x seed)
   Algorithm 1 sweep as ONE compiled program — the sub-network count M
   rides the scenario axis as a traced scalar, and each scenario's (T,)
   Theorem-1 error curve is reduced inside the scan (``store="gap"``).
6. Phase diagram: a (drop_prob x Gamma x seed) Algorithm 3 grid as ONE
   compiled program — belief-convergence rate per cell, with the (T,) worst
   log-ratio curves reduced inside the scan (nothing of size (K, T, N, m)
   ever exists).
7. Asynchronous execution: agents wake on independent clocks and consume
   bounded-staleness messages — a (wake-rate x staleness) grid rides the
   same vmap scenario axis via ``ExecutionPlan(async_=...)`` (execution
   knobs travel as a plan, never as loose kwargs).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    ExecutionPlan, HPSConfig, ByzantineConfig, make_hierarchy,
    make_confused_model, make_async_model, run_social_learning,
    run_byzantine_learning, attacks, healthy_networks,
    random_strongly_connected, stack_edge_lists, run_pushsum_sweep,
    run_hps_sweep, run_social_sweep,
)

# --- system: 3 sub-networks of 6/6/6 agents, complete intra-network graphs
topo = make_hierarchy([6, 6, 6], topology="complete", seed=0)
model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5, seed=0)
print(f"system: M={topo.M} networks, N={topo.N} agents, "
      f"m={model.m} hypotheses, theta* = {model.truth}")

# --- Algorithm 3: packet-dropping links -----------------------------------
cfg = HPSConfig(topo=topo, gamma_period=8, B=4, drop_prob=0.3)
res = run_social_learning(model, cfg, T=500, seed=0)
beliefs = np.asarray(res.beliefs)
print("\n[Alg 3] drop_prob=0.3, PS fusion every 8 steps:")
for t in (50, 150, 499):
    b = beliefs[t, :, model.truth]
    print(f"  t={t:4d}  belief in theta*: min={b.min():.4f} mean={b.mean():.4f}")
assert beliefs[-1, :, model.truth].min() > 0.95

# --- Algorithm 2: Byzantine agents ----------------------------------------
# Byzantine tolerance F=2 needs n_i >= 3F+1 = 7 agents per sub-network (A3)
# and per-network redundant observability (A4 survives removing F agents):
# confusion=0 keeps every agent informative about its assigned hypothesis.
topo = make_hierarchy([7, 7, 7], topology="complete", seed=0)
model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.0, seed=0)
byz = (2, 9)           # one compromised agent in each of networks 0 and 1
bcfg = ByzantineConfig(
    topo=topo, F=2, byz=byz, gamma_period=10,
    attack=attacks.truth_suppression(model.truth, magnitude=1e3),
)
C = healthy_networks(topo, bcfg.byz_mask(), bcfg.F)
print(f"\n[Alg 2] Byzantine agents {byz} run truth-suppression; C={C}")
bres = run_byzantine_learning(model, bcfg, T=500, seed=0)
dec = np.asarray(bres.decisions[-1])
normal = ~bcfg.byz_mask()
acc = (dec[normal] == model.truth).mean()
print(f"  normal-agent accuracy at T=500: {acc:.3f} "
      f"(decisions: {np.bincount(dec[normal], minlength=3)})")
assert acc == 1.0

# --- scenario sweep: 32 consensus runs in one compiled call ----------------
rng = np.random.default_rng(0)
el = stack_edge_lists([random_strongly_connected(64, 0.05, rng)
                       for _ in range(2)])
w = rng.normal(size=(64, 3)).astype(np.float32)
sweep = run_pushsum_sweep(w, el, T=300, drop_probs=[0.0, 0.3, 0.6, 0.9],
                          seeds=[0, 1, 2, 3], B=4)
err = np.asarray(sweep.err)
print(f"\n[sweep] {sweep.K} scenarios (2 graphs x 4 drop rates x 4 seeds), "
      f"one jitted vmapped scan:")
for dp in (0.0, 0.9):
    sel = np.asarray(sweep.drop_prob) == np.float32(dp)
    print(f"  drop={dp:.1f}  worst final consensus err: {err[sel, -1].max():.2e}")
assert err[:, -1].max() < 1e-2

# --- Algorithm 1 grid: topology x M x Γ x drop x seed in one call ----------
hier_a = make_hierarchy([6, 6, 6], topology="complete", seed=0)   # M=3
hier_b = make_hierarchy([9, 9], topology="complete", seed=1)      # M=2
w18 = np.random.default_rng(2).normal(size=(18, 3)).astype(np.float32)
bases = [HPSConfig(topo=t, gamma_period=8, B=2, drop_prob=0.0)
         for t in (hier_a, hier_b)]
hps = run_hps_sweep(w18, bases, T=2000, drop_probs=[0.0, 0.3],
                    gammas=[2, 8], seeds=[0, 1])   # store="gap" default
gaps = np.asarray(hps.gap)                          # (K, T) Thm-1 curves
print(f"\n[Alg 1 grid] {hps.K} HPS scenarios (2 hierarchies M∈{{3,2}} x "
      f"2 drops x 2 Γ x 2 seeds), one jitted vmapped scan;\n"
      f"  final consensus error per (M, Γ) cell (worst over drops/seeds):")
for m_val in (3, 2):
    cells = []
    for g in (2, 8):
        sel = (np.asarray(hps.M) == m_val) & (np.asarray(hps.gamma) == g)
        cells.append(f"Γ={g}:{gaps[sel, -1].max():.1e}")
    print(f"  M={m_val}  " + "  ".join(cells))
assert gaps[:, -1].max() < 5e-2   # every scenario reached consensus

# --- Algorithm 3 phase diagram: drop x Γ x seed in one compiled call -------
topo3 = make_hierarchy([6, 6, 6], topology="complete", seed=0)
model3 = make_confused_model(N=topo3.N, m=3, truth=1, confusion=0.5, seed=0)
base = HPSConfig(topo=topo3, gamma_period=8, B=4, drop_prob=0.0)
drops, gammas = [0.0, 0.3, 0.6], [4, 16]
sw = run_social_sweep(model3, base, T=400, drop_probs=drops, gammas=gammas,
                      seeds=[0, 1])
curves = np.asarray(sw.log_ratio)                 # (K, T) worst log-ratio
print(f"\n[phase diagram] {sw.K} Alg-3 scenarios "
      f"({len(drops)} drops x {len(gammas)} Γ x 2 seeds), one jitted "
      f"vmapped scan;\n  log-ratio decay rate per (drop, Γ) cell "
      f"(mean over seeds, nats/iter):")
for g in gammas:
    rates = []
    for dp in drops:
        sel = (np.asarray(sw.drop_prob) == np.float32(dp)) \
            & (np.asarray(sw.gamma) == g)
        rates.append(-(curves[sel, -1] - curves[sel, 99]).mean() / 300)
    cells = "  ".join(f"drop={d:.1f}:{r:.4f}" for d, r in zip(drops, rates))
    print(f"  Γ={g:2d}  {cells}")
assert (curves[:, -1] < -5.0).all()   # every scenario learned theta*

# --- async mode: a (wake-rate x staleness) grid in one compiled call -------
# Agents wake on independent Bernoulli-discretized Poisson clocks; an awake
# sender latches its message into a per-edge bounded buffer and delivery
# accepts snapshots up to `staleness` ticks old — so a sleeping sender's
# last message still arrives. wake=1.0/staleness=0 is bit-identical to the
# synchronous engine above.
wakes, stales = [1.0, 0.8, 0.6], [0, 4]
ams = [make_async_model(q, s) for q in wakes for s in stales]
asw = run_social_sweep(
    model3, base, T=400, drop_probs=[0.1], seeds=[0],
    plan=ExecutionPlan(store="log_ratio", async_=ams))
alr = np.asarray(asw.log_ratio)                   # (K, T), async minor-most
na = len(ams)
print(f"\n[async] {asw.K} Alg-3 scenarios (3 wake rates x 2 staleness "
      f"bounds), one jitted vmapped scan;\n  final worst log-ratio per "
      f"(wake, staleness) cell (more negative = learned faster):")
for qi, q in enumerate(wakes):
    cells = "  ".join(
        f"stale={s}:{alr[(qi * len(stales)) + si, -1]:+.1f}"
        for si, s in enumerate(stales))
    print(f"  wake={q:.1f}  {cells}")
assert np.isfinite(alr).all()
assert (alr[:, -1] < 0).all()     # every async cell still learned theta*
print("\nquickstart OK")
