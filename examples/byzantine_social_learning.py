"""Attack gallery: Algorithm 2 against every implemented Byzantine strategy,
plus the failure of the unfiltered baseline, and the Gamma (PS fusion
frequency) trade-off of Remark 3.

Run:  PYTHONPATH=src python examples/byzantine_social_learning.py
"""
import numpy as np

from repro.core import (
    ByzantineConfig, HPSConfig, make_hierarchy, make_confused_model,
    run_byzantine_learning, run_social_learning, attacks,
)

# confusion=0: every agent informative, so each network's A4 survives
# removing F agents (healthy_networks now checks this)
topo = make_hierarchy([7, 7, 7, 7], topology="complete", seed=0)
model = make_confused_model(N=topo.N, m=3, truth=0, confusion=0.0, seed=1)
byz = (2, 9)
normal = np.ones(topo.N, bool)
normal[list(byz)] = False

print(f"{topo.M} networks x 7 agents, F=2 Byzantine at {byz}, theta*=0\n")
print(f"{'attack':24s} {'filtered acc':>12s} {'unfiltered acc':>15s}")
for name, factory in attacks.ATTACKS.items():
    atk = factory(0) if name == "truth_suppression" else factory()
    accs = []
    for F in (2, 0):  # paper's filter vs no filter
        cfg = ByzantineConfig(topo=topo, F=F, byz=byz, gamma_period=10,
                              attack=atk)
        res = run_byzantine_learning(model, cfg, T=400, seed=0)
        dec = np.asarray(res.decisions[-1])
        accs.append((dec[normal] == model.truth).mean())
    print(f"{name:24s} {accs[0]:12.3f} {accs[1]:15.3f}")

print("\nRemark 3 — sparser PS fusion costs almost nothing (Alg 3, 30% drop):")
model2 = make_confused_model(N=topo.N, m=3, truth=0, confusion=0.5, seed=2)
for gamma in (4, 16, 64):
    cfg = HPSConfig(topo=topo, gamma_period=gamma, B=2, drop_prob=0.3)
    res = run_social_learning(model2, cfg, T=500, seed=1)
    b = np.asarray(res.beliefs[-1])[:, 0]
    print(f"  Gamma={gamma:3d}: PS messages={500 // gamma:3d}  "
          f"min belief in theta* = {b.min():.4f}")
print("\nbyzantine_social_learning OK")
