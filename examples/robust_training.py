"""End-to-end driver: decentralized training of the ~100M paper_sim model
with the paper's robust aggregation, a few hundred steps, with a live
Byzantine worker — the "train a ~100M model for a few hundred steps"
deliverable.

Each data worker holds its own model copy (the paper's per-agent belief);
gradients are fused by coordinate-wise trimmed mean (Algorithm 2's filter),
so the sign-flipping Byzantine worker cannot poison training. The consensus
spread across worker copies is the training-side analogue of Theorem 1's
consensus error.

Run (CPU, 8 fake devices, ~10 min):
  PYTHONPATH=src python examples/robust_training.py --steps 200
Quick check:
  PYTHONPATH=src python examples/robust_training.py --steps 20 --tiny
"""
import argparse
import os

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--devices", type=int, default=8)
ap.add_argument("--agg", default="trimmed_mean",
                choices=["mean", "trimmed_mean", "pushsum",
                         "hierarchical_trim"])
args = ap.parse_args()

os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}"
)

import dataclasses
import jax

from repro.configs import get_config, reduced
from repro.data import SyntheticLMData
from repro.distributed.aggregation import AggregatorConfig
from repro.distributed.trainer import (
    TrainConfig, make_train_step, param_spread,
    replicate_for_workers, worker_opt_init,
)
from repro.launch import compat
from repro.models import model as M
from repro.optim import AdamWConfig

mesh = compat.make_mesh((2, args.devices // 4, 2), ("pod", "data", "model"))
n_workers = 2 * (args.devices // 4)

cfg = get_config("paper_sim")            # ~100M params
if args.tiny:
    cfg = reduced(cfg)
cfg = dataclasses.replace(cfg, attn_impl="naive", dtype="float32")

tc = TrainConfig(
    arch=cfg,
    agg=AggregatorConfig(kind=args.agg, F=1, gossip_rounds=16,
                         gamma_period=4, drop_prob=0.1),
    opt=AdamWConfig(lr=3e-4, warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps),
    byzantine_workers=(1,),              # worker 1 sign-flips its gradients
    byzantine_scale=10.0,
)
print(f"arch={cfg.name} ({cfg.param_count()/1e6:.0f}M params) "
      f"agg={args.agg} workers={n_workers} byzantine={tc.byzantine_workers}")

data = SyntheticLMData(cfg.vocab, 128 if not args.tiny else 32, 8,
                       flavour="markov", n_agents=n_workers, seed=0)
params = M.init_params(jax.random.PRNGKey(0), cfg)
factory, _ = make_train_step(tc, mesh)
pw = replicate_for_workers(params, n_workers)
ow = worker_opt_init(pw)

with compat.set_mesh(mesh):
    step = jax.jit(factory(pw))
    spread_fn = jax.jit(param_spread)  # one executable, ordered collectives
    for s in range(args.steps):
        pw, ow, loss = step(pw, ow, data.batch(s), jax.random.PRNGKey(s))
        # serialize dispatch: overlapping executables can starve the
        # in-process CPU collective rendezvous on small hosts
        jax.block_until_ready(pw)
        if s % max(args.steps // 10, 1) == 0 or s == args.steps - 1:
            print(f"step {s:4d}  loss {float(loss):.4f}  "
                  f"consensus_spread {float(spread_fn(pw)):.3e}",
                  flush=True)
print("robust_training OK")
