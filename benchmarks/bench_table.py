"""Render the committed perf-trajectory artifacts as a markdown table.

Reads every ``results/BENCH_*.json`` (the merge-updated artifacts written
by ``benchmarks/run.py --json-dir``) and prints one markdown table per
artifact — the generator behind README.md's benchmark section:

    PYTHONPATH=src python -m benchmarks.bench_table [--only NAME ...]

Interpreter-mode Pallas rows are kept but labeled: on CPU they measure the
Pallas interpreter (equivalence testing), not the kernel, so they are not
comparable to the compiled XLA rows next to them.
"""
import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "..", "results")


def tables(only=None):
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "BENCH_*.json"))):
        tag = os.path.basename(path)[len("BENCH_"):-len(".json")]
        if only and tag not in only:
            continue
        with open(path) as f:
            rows = json.load(f)
        lines = [f"### {tag}", "",
                 "| benchmark | us/call | notes |",
                 "|---|---:|---|"]
        for name in sorted(rows):
            r = rows[name]
            notes = r["derived"].replace("|", "\\|")
            us = r["us_per_call"]
            # explicitly-skipped rows (derived starts "skipped=") carry
            # us_per_call null — render an em dash, not a crash
            cell = "—" if us is None else f"{us:.1f}"
            lines.append(f"| `{name}` | {cell} | {notes} |")
        out.append("\n".join(lines))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="restrict to these artifact tags (e.g. hps social)")
    args = ap.parse_args()
    print("\n\n".join(tables(args.only)))


if __name__ == "__main__":
    main()
