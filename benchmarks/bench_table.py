"""Render the committed perf-trajectory artifacts as a markdown table.

Reads every ``results/BENCH_*.json`` (the merge-updated artifacts written
by ``benchmarks/run.py --json-dir``) and prints one markdown table per
artifact — the generator behind README.md's benchmark section:

    PYTHONPATH=src python -m benchmarks.bench_table [--only NAME ...]

Rows that record compiled byte traffic get two extra columns:

* **bytes/step** — the ``bytes_per_step=`` tag: XLA ``cost_analysis``
  "bytes accessed" of the compiled program, divided by the scan length;
* **roofline** — ``bytes_per_step / budget_bytes=``: the fraction of the
  analytic per-step byte budget (:mod:`repro.statics.memory`,
  policy-aware) the compiled program actually moves. The model is an
  upper bound — every state leaf read and written once per round, no
  fusion credit — so the fraction sits at or below 1.0; XLA's loop
  fusion typically lands ~0.3–0.6. A fraction above 1 means the program
  blew its budget; ``repro.statics budget`` validates the same pair of
  numbers and fails CI on that.

Interpreter-mode Pallas rows are kept but labeled: on CPU they measure the
Pallas interpreter (equivalence testing), not the kernel, so they are not
comparable to the compiled XLA rows next to them.
"""
import argparse
import glob
import json
import os
import re

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "..", "results")

_BYTES_RE = re.compile(r"(?:^|;)bytes_per_step=([0-9.eE+-]+)")
_BUDGET_RE = re.compile(r"(?:^|;)budget_bytes=([0-9.eE+-]+)")


def _byte_cells(derived: str) -> tuple[str, str]:
    """(bytes/step, roofline-fraction) cells from a derived tag — em
    dashes when the row doesn't record byte traffic."""
    b_m = _BYTES_RE.search(derived)
    g_m = _BUDGET_RE.search(derived)
    if not b_m:
        return "—", "—"
    bps = float(b_m.group(1))
    if bps != bps:          # NaN: backend didn't report cost_analysis
        return "n/a", "—"
    cell = f"{bps / 1e6:.2f} MB"
    if not g_m:
        return cell, "—"
    return cell, f"{bps / float(g_m.group(1)):.2f}"


def tables(only=None):
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "BENCH_*.json"))):
        tag = os.path.basename(path)[len("BENCH_"):-len(".json")]
        if only and tag not in only:
            continue
        with open(path) as f:
            rows = json.load(f)
        lines = [f"### {tag}", "",
                 "| benchmark | us/call | bytes/step | roofline | notes |",
                 "|---|---:|---:|---:|---|"]
        for name in sorted(rows):
            r = rows[name]
            notes = r["derived"].replace("|", "\\|")
            us = r["us_per_call"]
            # explicitly-skipped rows (derived starts "skipped=") carry
            # us_per_call null — render an em dash, not a crash
            cell = "—" if us is None else f"{us:.1f}"
            bps, roof = _byte_cells(r["derived"])
            lines.append(f"| `{name}` | {cell} | {bps} | {roof} | {notes} |")
        out.append("\n".join(lines))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="restrict to these artifact tags (e.g. hps social)")
    args = ap.parse_args()
    print("\n\n".join(tables(args.only)))


if __name__ == "__main__":
    main()
