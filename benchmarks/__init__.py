"""Benchmark modules, one per paper claim; driven by benchmarks/run.py."""
import json
import os

__all__ = ["merge_bench_json"]


def merge_bench_json(path: str, rows) -> None:
    """Merge-update a BENCH_*.json artifact: keys not re-measured by this
    invocation are preserved, and NaN rows (a failed sub-benchmark's
    degraded placeholder) are dropped rather than serialized — bare ``NaN``
    is not RFC-8259 JSON and breaks strict parsers of the perf-trajectory
    artifact.

    Exception: rows whose ``derived`` starts with ``skipped=`` are an
    *explicit* skip (e.g. a sharded benchmark on a single-device host) and
    are kept with ``us_per_call: null`` — the artifact then records WHY the
    row is unmeasured instead of silently losing it, and downstream
    consumers (``bench_table``, ``run.py --check``,
    ``repro.statics.memory.validate_bench``) all understand the marker.
    The single shared writer for run.py --json-dir and the standalone
    module __main__ blocks."""
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            merged = json.load(f)
    merged.update({
        name: {"us_per_call": us if us == us else None, "derived": derived}
        for name, us, derived in rows
        if us == us or str(derived).startswith("skipped=")
    })
    with open(path, "w") as f:
        json.dump(merged, f, indent=1, allow_nan=False)
