"""Benchmark modules, one per paper claim; driven by benchmarks/run.py."""
import json
import os

__all__ = ["merge_bench_json"]


def merge_bench_json(path: str, rows) -> None:
    """Merge-update a BENCH_*.json artifact: keys not re-measured by this
    invocation are preserved, and NaN rows (a failed sub-benchmark's
    degraded placeholder) are dropped rather than serialized — bare ``NaN``
    is not RFC-8259 JSON and breaks strict parsers of the perf-trajectory
    artifact. The single shared writer for run.py --json-dir and the
    standalone module __main__ blocks."""
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            merged = json.load(f)
    merged.update({name: {"us_per_call": us, "derived": derived}
                   for name, us, derived in rows if us == us})
    with open(path, "w") as f:
        json.dump(merged, f, indent=1, allow_nan=False)
