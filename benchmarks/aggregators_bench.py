"""Aggregator micro-benchmark: us/call for the paper's gradient-consensus
strategies at increasing gradient sizes (single host device; the multi-
device schedule cost is covered by the dry-run roofline numbers)."""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.trimmed_mean.ops import trimmed_mean
from repro.kernels.trimmed_mean.ref import trimmed_mean_ref


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def rows():
    out = []
    rng = np.random.default_rng(0)
    # modest D: the kernel runs in interpret mode on CPU (python per block);
    # on-TPU block counts scale to full gradient sizes
    for W, D in ((16, 1 << 14), (16, 1 << 16), (32, 1 << 16)):
        x = jnp.asarray(rng.normal(size=(W, D)).astype(np.float32))
        ref = jax.jit(lambda a: trimmed_mean_ref(a, 3))
        ker = jax.jit(lambda a: trimmed_mean(a, 3))
        t_ref = _time(ref, x)
        t_ker = _time(ker, x)
        out.append((f"trim_sort_ref_W{W}_D{D}", t_ref, "sort-based"))
        out.append((f"trim_kernel_W{W}_D{D}", t_ker,
                    f"speedup={t_ref/max(t_ker,1e-9):.2f}x(interpret-mode)"))
        mean = jax.jit(lambda a: a.mean(0))
        out.append((f"mean_W{W}_D{D}", _time(mean, x), "baseline"))
    return out
