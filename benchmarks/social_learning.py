"""Algorithm 3 / Theorem 2 benchmarks: the fused social-learning engine.

Three claim families:
 * convergence — iterations to drive every agent's belief in theta* past
   0.9 for increasing drop probabilities (``social_conv_drop*`` rows; the
   paper's claim: convergence persists for any drop rate given B-window
   delivery, at a rate degraded through Theorem 1's gamma);
 * per-step cost of the fused engine at N in {1024, 16384} through the
   ``backend="xla"|"pallas"`` switch (``social_step_*`` rows) — runtimes
   are built dense-free via :func:`graphs.block_complete_edge_list`, so no
   (N, N) adjacency ever exists, and ``store="final"`` keeps the scan from
   materializing (T, N, m);
 * a (drop_prob x Gamma x seed) grid compiled ONCE as a single vmapped
   scan (``social_sweep_dropxgamma`` row;
   :func:`repro.core.sweeps.run_social_sweep`).

On CPU the Pallas rows run ``interpret=True`` equivalence mode (tagged
``mode=interpret``; the perf gate skips them) — the compiled comparison is
TPU-only, as with the push-sum and trim kernel rows.
"""
import time

import jax
import numpy as np

from repro.core.graphs import block_complete_edge_list, make_hierarchy
from repro.core.hps import HPSConfig
from repro.core.signals import make_confused_model
from repro.core.social import (
    run_social_learning,
    run_social_runtime,
    social_runtime_from_edge_list,
)
from repro.core.sweeps import run_social_sweep


def _conv_rows():
    out = []
    topo = make_hierarchy([6, 6, 6], topology="complete", seed=2)
    model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5, seed=0)
    T = 700
    for drop in (0.0, 0.3, 0.6):
        cfg = HPSConfig(topo=topo, gamma_period=8, B=4, drop_prob=drop)
        t0 = time.perf_counter()
        res = run_social_learning(model, cfg, T=T, seed=0)
        b = np.asarray(res.beliefs)
        wall = (time.perf_counter() - t0) / T * 1e6
        hit = np.nonzero((b[:, :, 1] > 0.9).all(axis=1))[0]
        t_conv = int(hit[0]) if len(hit) else -1
        out.append((f"social_conv_drop{drop}", wall,
                    f"t_to_0.9={t_conv};final_min={b[-1,:,1].min():.3f}"))
    return out


def _step_setup(N):
    """N/8 complete 8-agent networks, built dense-free (no (N, N) array)."""
    el, rep_mask = block_complete_edge_list([8] * (N // 8))
    model = make_confused_model(N=N, m=3, truth=0, confusion=0.75, seed=1)
    rt = social_runtime_from_edge_list(
        el, rep_mask, drop_prob=0.1, gamma_period=8, B=4
    )
    return model, rt, N // 8


def _time_run(model, rt, M, T, backend, policy=None):
    from repro.core.plan import ExecutionPlan

    plan = ExecutionPlan(backend=backend, store="final", policy=policy)
    t0 = time.perf_counter()
    jax.block_until_ready(run_social_runtime(
        model, rt, M, T, seed=0, plan=plan,
    ).beliefs)
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    jax.block_until_ready(run_social_runtime(
        model, rt, M, T, seed=0, plan=plan,
    ).beliefs)
    return (time.perf_counter() - t0) / T * 1e6, compile_wall


def _bytes_per_step(model, rt, M, T, backend, policy=None) -> float:
    """Compiled per-step 'bytes accessed' of the fused engine — the number
    the precision policy halves (cost_analysis over an explicit jit of the
    same call; NaN when the backend doesn't report it)."""
    from repro.core.plan import ExecutionPlan

    fn = jax.jit(lambda rt_: run_social_runtime(
        model, rt_, M, T, seed=0,
        plan=ExecutionPlan(backend=backend, store="final", policy=policy),
    ).beliefs)
    try:
        cost = fn.lower(rt).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        return float(cost["bytes accessed"]) / T
    except Exception:
        return float("nan")


def _step_rows(smoke: bool):
    """social_step_{xla,pallas}_N{...}: fused-engine per-step cost, plus a
    ``social_step_xla_bf16_N{...}`` row with the bf16 storage policy
    (:mod:`repro.core.precision`) — both xla rows record compiled
    bytes_per_step so the bandwidth claim rides the artifact."""
    out = []
    sizes = (1024,) if smoke else (1024, 16384)
    from repro.statics.memory import social_step_bytes

    for N in sizes:
        model, rt, M = _step_setup(N)
        E = int(rt.src.shape[0])
        xla_us, compile_s = _time_run(model, rt, M, T=30, backend="xla")
        bps = _bytes_per_step(model, rt, M, 30, "xla")
        budget = social_step_bytes(N, E, 3)
        out.append((
            f"social_step_xla_N{N}", xla_us,
            f"E={E};m=3;Gamma=8;drop=0.1;store=final;"
            f"bytes_per_step={bps:.0f};budget_bytes={budget};"
            f"compile_s={compile_s:.1f}",
        ))
        bf_us, bf_compile_s = _time_run(model, rt, M, T=30, backend="xla",
                                        policy="bf16")
        bf_bps = _bytes_per_step(model, rt, M, 30, "xla", policy="bf16")
        bf_budget = social_step_bytes(N, E, 3, policy="bf16")
        out.append((
            f"social_step_xla_bf16_N{N}", bf_us,
            f"E={E};m=3;Gamma=8;drop=0.1;store=final;policy=bf16;"
            f"bytes_per_step={bf_bps:.0f};budget_bytes={bf_budget};"
            f"budget_vs_fp32={bf_budget / budget:.3f};"
            f"compile_s={bf_compile_s:.1f}",
        ))
        mode = "interpret" if jax.default_backend() != "tpu" else "compiled"
        T_p = 4 if mode == "interpret" else 30
        pallas_us, compile_s = _time_run(model, rt, M, T=T_p,
                                         backend="pallas")
        out.append((
            f"social_step_pallas_N{N}", pallas_us,
            f"E={E};m=3;Gamma=8;drop=0.1;store=final;mode={mode};"
            f"compile_s={compile_s:.1f}",
        ))
    return out


def _sweep_row(smoke: bool):
    """drop_prob x Gamma x seed grid: one trace, one compiled program."""
    topo = make_hierarchy([6, 6, 6], topology="complete", seed=0)
    model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5, seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=8, B=4, drop_prob=0.0)
    drops = (0.0, 0.3) if smoke else (0.0, 0.3, 0.6, 0.9)
    gammas = (4, 16) if smoke else (4, 8, 16)
    seeds = list(range(2 if smoke else 4))
    T = 50 if smoke else 300

    def go():
        res = run_social_sweep(model, cfg, T, drop_probs=drops,
                               gammas=gammas, seeds=seeds)
        jax.block_until_ready(res.log_ratio)
        return res

    t0 = time.perf_counter()
    res = go()
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = go()
    wall = time.perf_counter() - t0
    final = np.asarray(res.beliefs)[:, :, model.truth]   # (K, N)
    return (
        f"social_sweep_dropxgamma{res.K}", wall / res.K * 1e6,
        f"scenarios={res.K};drops={len(drops)};gammas={len(gammas)};"
        f"seeds={len(seeds)};T={T};single_jit=true;"
        f"belief_min={final.min():.3f};compile_s={compile_wall:.1f}",
    )


def _churn_row(smoke: bool):
    """Churn-rate axis of the unified fault plane: per-round leave
    probabilities 0, 2%, 10% (rejoin at 30%) ride the sweep's fault
    dimension in ONE compiled program. The derived string records the
    worst final belief in theta* per churn rate — the paper's convergence
    claim degrading gracefully as agents leave and rejoin with stale
    state (churn=0 is the degenerate model, regression-tested equal to
    the fault-free engine in tests/test_faults.py)."""
    from repro.core.faults import make_fault_model

    topo = make_hierarchy([6, 6, 6], topology="complete", seed=0)
    model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5,
                                seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=8, B=4, drop_prob=0.3)
    churns = (0.0, 0.02, 0.1)
    faults = [make_fault_model(leave_prob=c, join_prob=0.3)
              for c in churns]
    T = 60 if smoke else 400
    seeds = list(range(2 if smoke else 4))

    def go():
        from repro.core.plan import ExecutionPlan

        res = run_social_sweep(model, cfg, T, seeds=seeds,
                               plan=ExecutionPlan(faults=faults))
        jax.block_until_ready(res.beliefs)
        return res

    t0 = time.perf_counter()
    res = go()
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = go()
    wall = time.perf_counter() - t0
    nf = len(faults)
    final = np.asarray(res.beliefs)[:, :, model.truth]   # (K, N)
    mins = [float(final[i::nf].min()) for i in range(nf)]
    tags = ";".join(f"belief_min_churn{c}={m:.3f}"
                    for c, m in zip(churns, mins))
    return (
        "social_conv_churn", wall / res.K * 1e6,
        f"scenarios={res.K};churns={','.join(map(str, churns))};"
        f"join=0.3;T={T};single_jit=true;{tags};"
        f"compile_s={compile_wall:.1f}",
    )


def _async_row(smoke: bool):
    """(wake-rate x staleness) grid of the async event-driven mode in ONE
    compiled program (the async axis rides the vmap scenario axis,
    minor-most), plus the ROADMAP acceptance comparison. The config
    removes the paper's forced B-window delivery (B >> T) so the raw
    delivery rate is what matters; with the confusion=0.5 model a network
    whose mixing falls behind its innovation accumulation locks into the
    WRONG hypothesis (log-ratio saturates at the fp32 belief floor,
    +87.3). At wake 0.6 the async engine keeps converging — asleep agents
    pause observation too, so the mixing/innovation ratio stays healthy
    and the stale buffers keep information flowing — while the
    synchronous engine run at the equivalent same-tick delivery rate
    (a staleness-0 rendezvous needs sender and receiver awake:
    ``p_sync = 1 - q*(1-p)*q = 0.676``) stalls. The derived string
    records the median final log-ratio per (wake, staleness) cell and
    the stalled sync reference."""
    from repro.core.asyncrony import make_async_model
    from repro.core.plan import ExecutionPlan

    topo = make_hierarchy([6, 6, 6], topology="complete", seed=0)
    model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5,
                                seed=0)
    p = 0.1
    no_window = 1_000_000            # B >> T: no forced-delivery round
    cfg = HPSConfig(topo=topo, gamma_period=8, B=no_window, drop_prob=p)
    wakes = (1.0, 0.9, 0.6)
    stales = (0, 2, 8)
    grid = [(q, s) for q in wakes for s in stales]
    ams = [make_async_model(q, s) for q, s in grid]
    T = 80 if smoke else 600
    seeds = [0, 1] if smoke else [0, 1, 2, 3]

    def go():
        res = run_social_sweep(
            model, cfg, T, seeds=seeds,
            plan=ExecutionPlan(store="log_ratio", async_=ams))
        jax.block_until_ready(res.log_ratio)
        return res

    t0 = time.perf_counter()
    res = go()
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = go()
    wall = time.perf_counter() - t0

    na = len(ams)
    lr = np.asarray(res.log_ratio)          # (K, T), async minor-most
    med = [float(np.median(lr[a::na, -1])) for a in range(na)]
    tags = ";".join(f"lr_q{q}_s{s}={v:.2f}"
                    for (q, s), v in zip(grid, med))

    # the stall reference: sync at the q=0.6 cells' equivalent
    # same-tick delivery rate
    q = 0.6
    p_sync = 1.0 - q * (1.0 - p) * q
    sync = run_social_sweep(
        model, cfg, T, drop_probs=[p_sync], seeds=seeds,
        plan=ExecutionPlan(store="log_ratio"))
    sync_med = float(np.median(np.asarray(sync.log_ratio)[:, -1]))
    async_med = med[grid.index((0.6, 8))]

    return (
        f"social_async_wakexstale{res.K}", wall / res.K * 1e6,
        f"scenarios={res.K};wakes={','.join(map(str, wakes))};"
        f"stales={','.join(map(str, stales))};drop={p};B=no_window;"
        f"T={T};single_jit=true;{tags};"
        f"sync_equiv_drop={p_sync:.3f};lr_sync_equiv={sync_med:.2f};"
        f"async_beats_stalled_sync={async_med < 0.0 <= sync_med};"
        f"compile_s={compile_wall:.1f}",
    )


def rows(smoke: bool = False):
    out = [] if smoke else _conv_rows()
    out.extend(_step_rows(smoke))
    out.append(_sweep_row(smoke))
    out.append(_churn_row(smoke))
    out.append(_async_row(smoke))
    return out
