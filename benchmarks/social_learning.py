"""Theorem 2 benchmark: non-Bayesian learning under packet drops.

Derived metric: iterations to drive every agent's belief in theta* past
0.9, for increasing drop probabilities. The paper's claim: convergence
persists for any drop rate given B-window delivery, at a rate degraded
through gamma (Theorem 1's constant).
"""
import time

import numpy as np

from repro.core.graphs import make_hierarchy
from repro.core.hps import HPSConfig
from repro.core.signals import make_confused_model
from repro.core.social import run_social_learning


def rows():
    out = []
    topo = make_hierarchy([6, 6, 6], topology="complete", seed=2)
    model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5, seed=0)
    T = 700
    for drop in (0.0, 0.3, 0.6):
        cfg = HPSConfig(topo=topo, gamma_period=8, B=4, drop_prob=drop)
        t0 = time.perf_counter()
        res = run_social_learning(model, cfg, T=T, seed=0)
        b = np.asarray(res.beliefs)
        wall = (time.perf_counter() - t0) / T * 1e6
        hit = np.nonzero((b[:, :, 1] > 0.9).all(axis=1))[0]
        t_conv = int(hit[0]) if len(hit) else -1
        out.append((f"thm2_social_drop{drop}", wall,
                    f"t_to_0.9={t_conv};final_min={b[-1,:,1].min():.3f}"))
    return out
