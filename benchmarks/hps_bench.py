"""Theorem 1 benchmarks: the fused hierarchical push-sum (HPS) engine.

Three claim families:
 * consensus-decay claims of the paper on the fused engine, with the (T,)
   error curves reduced in-scan via ``store="gap"`` (no (T, N, d) history):
   smaller B (more reliable links) => faster; more sub-networks (smaller
   D*) => faster than one gigantic network (Remark 2); exponential decay
   checkpoints (``hps_consensus_*`` / ``hps_decay_checkpoints`` rows);
 * per-step cost of the fused engine at N in {1024, 16384} through the
   ``backend="xla"|"pallas"`` switch (``hps_step_*`` rows) — runtimes are
   built dense-free via :func:`graphs.hier_edge_list`, so no (N, N)
   adjacency ever exists, and ``store="final"`` keeps the scan from
   materializing (T, N, d);
 * a (topology x M x Gamma x drop x seed) grid compiled ONCE as a single
   vmapped scan — the sub-network count M rides the scenario axis as a
   traced scalar (``hps_grid_topoxMxGxD`` row;
   :func:`repro.core.sweeps.run_hps_grid`).

On CPU the Pallas rows run ``interpret=True`` equivalence mode (tagged
``mode=interpret``; the perf gate skips them) — the compiled comparison is
TPU-only, as with the push-sum, trim and innovation kernel rows.
"""
import time

import jax
import numpy as np

from repro.core.graphs import hier_edge_list, make_hierarchy
from repro.core.hps import HPSConfig, hps_runtime_from_edge_list, run_hps, run_hps_runtime
from repro.core.sweeps import run_hps_grid


def _consensus_rows():
    out = []
    rng = np.random.default_rng(0)

    def gap_curve(sizes, gamma, B, drop, T, topology="complete", seed=0):
        topo = make_hierarchy(sizes, topology=topology, seed=seed)
        w = rng.normal(size=(topo.N, 4)).astype(np.float32)
        cfg = HPSConfig(topo=topo, gamma_period=gamma, B=B, drop_prob=drop)
        t0 = time.perf_counter()
        err = np.asarray(run_hps(w, cfg, T, seed=seed, store="gap").gap)
        wall = (time.perf_counter() - t0) / T * 1e6
        return wall, err

    # B sweep (drop forced-delivery window) under heavy loss
    for B in (1, 2, 8):
        wall, err = gap_curve([6, 6, 6], gamma=8, B=B, drop=0.7, T=600)
        out.append((f"hps_consensus_B{B}", wall, f"err_t300={err[300]:.2e}"))
    # M sweep at fixed N=24 on RINGS: hierarchy shrinks the diameter D*
    # (Remark 2) — one 24-ring (D=23) vs four 6-rings (D=5) + PS fusion
    for sizes in ([24], [12, 12], [6, 6, 6, 6]):
        wall, err = gap_curve(sizes, gamma=4, B=2, drop=0.2, T=900,
                              topology="ring")
        out.append((f"hps_consensus_ringM{len(sizes)}", wall,
                    f"err_t600={err[600]:.2e}"))
    # exponential decay checkpoints
    wall, err = gap_curve([6, 6, 6], gamma=4, B=1, drop=0.1, T=600)
    halves = [float(err[t]) for t in (100, 200, 400)]
    out.append(("hps_decay_checkpoints", wall,
                "err(100;200;400)=" + ";".join(f"{h:.1e}" for h in halves)))
    return out


def _step_setup(N):
    """N/8 complete 8-agent networks, built dense-free (no (N, N) array)."""
    el, rep_mask = hier_edge_list([8] * (N // 8), topology="complete")
    rt = hps_runtime_from_edge_list(
        el, rep_mask, drop_prob=0.1, gamma_period=8, B=4
    )
    w = np.random.default_rng(1).normal(size=(N, 4)).astype(np.float32)
    return rt, w


def _time_run(w, rt, T, backend):
    t0 = time.perf_counter()
    jax.block_until_ready(run_hps_runtime(
        w, rt, T, seed=0, backend=backend, store="final"
    ).ratio)
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    jax.block_until_ready(run_hps_runtime(
        w, rt, T, seed=0, backend=backend, store="final"
    ).ratio)
    return (time.perf_counter() - t0) / T * 1e6, compile_wall


def _step_rows(smoke: bool):
    """hps_step_{xla,pallas}_N{1024,16384}: fused-engine per-step cost."""
    out = []
    sizes = (1024,) if smoke else (1024, 16384)
    for N in sizes:
        rt, w = _step_setup(N)
        E = int(rt.src.shape[0])
        xla_us, compile_s = _time_run(w, rt, T=30, backend="xla")
        out.append((
            f"hps_step_xla_N{N}", xla_us,
            f"E={E};d=4;Gamma=8;drop=0.1;store=final;"
            f"compile_s={compile_s:.1f}",
        ))
        mode = "interpret" if jax.default_backend() != "tpu" else "compiled"
        T_p = 4 if mode == "interpret" else 30
        pallas_us, compile_s = _time_run(w, rt, T=T_p, backend="pallas")
        out.append((
            f"hps_step_pallas_N{N}", pallas_us,
            f"E={E};d=4;Gamma=8;drop=0.1;store=final;mode={mode};"
            f"compile_s={compile_s:.1f}",
        ))
    return out


def _grid_row(smoke: bool):
    """topology x M x Gamma x drop x seed grid: one trace, one program."""
    topos = [
        make_hierarchy([6, 6, 6], topology="complete", seed=0),
        make_hierarchy([6, 6, 6], topology="ring+", extra_edge_prob=0.8,
                       seed=1),
        make_hierarchy([9, 9], topology="complete", seed=2),
        make_hierarchy([3] * 6, topology="complete", seed=3),
    ]
    cfgs = [
        HPSConfig(topo=t, gamma_period=g, B=2, drop_prob=d)
        for t in topos for g in (4, 8) for d in (0.0, 0.3)
    ]
    seeds = list(range(3))
    T = 50 if smoke else 300
    w = np.random.default_rng(0).normal(size=(18, 3)).astype(np.float32)

    def go():
        res = run_hps_grid(w, cfgs, T, seeds=seeds)
        jax.block_until_ready(res.gap)
        return res

    t0 = time.perf_counter()
    res = go()
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = go()
    wall = time.perf_counter() - t0
    gap = np.asarray(res.gap)
    Ms = sorted(set(np.asarray(res.M).tolist()))
    # T in the name: the smoke and full variants time different horizons
    # and must not ratchet each other's baseline under --json-dir
    return (
        f"hps_grid_topoxMxGxD{res.K}_T{T}", wall / res.K * 1e6,
        f"scenarios={res.K};topos=4;Ms={Ms};gammas=2;drops=2;"
        f"seeds={len(seeds)};T={T};single_jit=true;"
        f"worst_final_gap={gap[:, -1].max():.2e};"
        f"compile_s={compile_wall:.1f}",
    )


def rows(smoke: bool = False):
    out = [] if smoke else _consensus_rows()
    out.extend(_step_rows(smoke))
    out.append(_grid_row(smoke))
    return out
