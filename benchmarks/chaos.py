"""Chaos lane: high-burst x high-churn fault-grid smoke over all four engines.

This is the nightly/label-gated stress companion to the unified fault
plane (:mod:`repro.core.faults`). It runs every engine's sweep entry
under a grid of SEVERE fault models — long Gilbert-Elliott bursts (mean
8 and 32 rounds at a 50% stationary bad fraction), heavy churn (10% and
30% per-round leave probability) and a coin-flip parameter server — and
asserts the engines' graceful-degradation contracts instead of timing
anything:

* every output stays finite (no NaN/Inf escapes the scan under any
  fault severity);
* push-sum conserves the mass invariant through churn (dead agents
  freeze with their mass; rejoiners pick up stale but mass-consistent
  state);
* the whole fault grid runs as ONE compiled program per engine (the
  fault axis rides the vmap scenario axis — compiling per severity
  would be the retrace bug the statics lint exists to catch).

Exit code is non-zero on any violated contract, so the CI chaos job can
gate on it directly: ``python -m benchmarks.chaos`` (``--quick`` for a
laptop-sized run).
"""
import sys
import time

import jax
import numpy as np

from repro.core import attacks
from repro.core.byzantine import ByzantineConfig
from repro.core.faults import gilbert_elliott_model
from repro.core.graphs import (
    make_hierarchy,
    random_strongly_connected_edge_list,
)
from repro.core.hps import HPSConfig
from repro.core.plan import ExecutionPlan
from repro.core.pushsum import sparse_mass_invariant
from repro.core.signals import make_confused_model
from repro.core.sweeps import (
    run_byzantine_sweep,
    run_hps_sweep,
    run_pushsum_sweep,
    run_social_sweep,
)

# the severity grid: burst length x churn rate, everything else pinned
# harsh (50% stationary bad fraction, coin-flip PS, 25% rejoin rate)
BURSTS = (8.0, 32.0)
CHURNS = (0.1, 0.3)


def fault_grid():
    return [
        gilbert_elliott_model(L, 0.5, leave_prob=c, join_prob=0.25,
                              ps_crash_prob=0.5)
        for L in BURSTS for c in CHURNS
    ]


def _finite(name, *arrays):
    bad = [a for a in arrays if not np.isfinite(np.asarray(a)).all()]
    if bad:
        print(f"FAIL {name}: non-finite output under chaos grid")
        return 1
    print(f"ok   {name}: all outputs finite")
    return 0


def chaos_pushsum(quick):
    n, t = (64, 40) if quick else (512, 120)
    rng = np.random.default_rng(0)
    el = random_strongly_connected_edge_list(n, 2.0, rng)
    w = rng.normal(size=(n, 3)).astype(np.float32)
    res = run_pushsum_sweep(w, el, t, drop_probs=[0.2, 0.6], seeds=[0, 1],
                            B=4, plan=ExecutionPlan(faults=fault_grid()))
    fails = _finite(f"pushsum  K={res.err.shape[0]}", res.err, res.mass_gap)
    gap = float(np.abs(np.asarray(res.mass_gap)).max())
    if gap > 1e-2:
        print(f"FAIL pushsum: mass invariant broken under churn "
              f"(gap {gap:.2e})")
        fails += 1
    else:
        print(f"ok   pushsum: mass conserved through churn "
              f"(gap {gap:.2e})")
    return fails


def chaos_social(quick):
    topo = make_hierarchy([6, 6, 6], topology="complete", seed=0)
    model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5,
                               seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=4, B=4, drop_prob=0.4)
    t = 40 if quick else 150
    res = run_social_sweep(model, cfg, t, seeds=[0, 1],
                           plan=ExecutionPlan(faults=fault_grid()))
    return _finite(f"social   K={res.K}", res.beliefs, res.log_ratio)


def chaos_hps(quick):
    topo = make_hierarchy([6, 6, 6], topology="complete", seed=1)
    w = np.random.default_rng(2).normal(size=(18, 3)).astype(np.float32)
    cfg = HPSConfig(topo=topo, gamma_period=4, B=4, drop_prob=0.4)
    t = 40 if quick else 150
    res = run_hps_sweep(w, cfg, t, seeds=[0, 1],
                        plan=ExecutionPlan(faults=fault_grid()))
    return _finite(f"hps      K={res.gap.shape[0]}", res.ratio, res.gap)


def chaos_byzantine(quick):
    topo = make_hierarchy([7] * 4, topology="complete", seed=0)
    model = make_confused_model(N=28, m=3, truth=0, confusion=0.3, seed=1)
    cfg = ByzantineConfig(topo=topo, F=1, byz=(2,), gamma_period=4,
                          attack=attacks.large_value())
    t = 20 if quick else 60
    fails = 0
    # the byzantine sweep bakes fault scalars per compile: iterate the
    # grid explicitly (cache keyed on the fault fingerprint)
    for fm in fault_grid():
        res = run_byzantine_sweep(model, cfg, t, seeds=[0, 1],
                                  plan=ExecutionPlan(store="final",
                                                     faults=fm))
        for tag, r in res.items():
            fails += _finite(f"byzantine[{tag}]", r.r)
    return fails


def chaos_async(quick):
    """async x burst x churn: the event-driven mode composed with the
    SEVERE fault grid — sparse wake clocks (30%) with deep staleness
    (8 ticks) riding the async axis while every link burns through long
    Gilbert-Elliott bursts and agents churn. Contracts: everything
    finite, and push-sum mass conserved under the triple composition
    (asleep agents and churn-dead agents both freeze with their mass;
    the telescoping buffer delivery cannot create or destroy any)."""
    from repro.core.asyncrony import make_async_model

    asyncs = [make_async_model(1.0, 0), make_async_model(0.3, 8)]
    fails = 0

    n, t = (64, 40) if quick else (256, 120)
    rng = np.random.default_rng(0)
    el = random_strongly_connected_edge_list(n, 2.0, rng)
    w = rng.normal(size=(n, 3)).astype(np.float32)
    res = run_pushsum_sweep(
        w, el, t, drop_probs=[0.4], seeds=[0, 1], B=4,
        plan=ExecutionPlan(faults=fault_grid(), async_=asyncs))
    fails += _finite(f"pushsum+async  K={res.err.shape[0]}",
                     res.err, res.mass_gap)
    gap = float(np.abs(np.asarray(res.mass_gap)).max())
    if gap > 1e-2:
        print(f"FAIL pushsum+async: mass invariant broken under "
              f"async x burst x churn (gap {gap:.2e})")
        fails += 1
    else:
        print(f"ok   pushsum+async: mass conserved under "
              f"async x burst x churn (gap {gap:.2e})")

    topo = make_hierarchy([6, 6, 6], topology="complete", seed=0)
    model = make_confused_model(N=topo.N, m=3, truth=1, confusion=0.5,
                                seed=0)
    cfg = HPSConfig(topo=topo, gamma_period=4, B=4, drop_prob=0.4)
    t = 40 if quick else 150
    res = run_social_sweep(
        model, cfg, t, seeds=[0, 1],
        plan=ExecutionPlan(faults=fault_grid(), async_=asyncs))
    fails += _finite(f"social+async   K={res.K}",
                     res.beliefs, res.log_ratio)
    return fails


def main(argv=None) -> int:
    quick = "--quick" in (argv if argv is not None else sys.argv[1:])
    grid = fault_grid()
    print(f"# chaos grid: {len(grid)} fault models "
          f"(bursts {BURSTS} x churn {CHURNS}, bad_frac=0.5, "
          f"ps_crash=0.5), quick={quick}")
    t0 = time.perf_counter()
    fails = 0
    fails += chaos_pushsum(quick)
    fails += chaos_social(quick)
    fails += chaos_hps(quick)
    fails += chaos_byzantine(quick)
    fails += chaos_async(quick)
    print(f"# chaos lane: {fails} failures in "
          f"{time.perf_counter() - t0:.1f}s")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
