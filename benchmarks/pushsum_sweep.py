"""Sparse-core + sweep-engine benchmark.

Claims pinned:
 * the edge-list core runs N=1024 agents on a sparse digraph (E << N^2)
   without ever allocating an (N, N) or (N, N, d) array — the dense
   reference would need ~N^2 d floats of rho alone (16 GB at N=1024,
   d=4096-equivalent sweeps);
 * a >= 32-scenario grid (topology draws x drop probs x seeds) runs as ONE
   jitted vmapped scan (`repro.core.sweeps.run_pushsum_sweep`);
 * consensus error decays in every scenario (Theorem 1 across the grid).

Emits name,us_per_call,derived rows via :func:`rows`. The machine-readable
``BENCH_pushsum_sweep.json`` perf-trajectory artifact is written to
``results/`` when run standalone (``python -m benchmarks.pushsum_sweep``);
under ``benchmarks/run.py`` the ``--json-dir`` flag is the single writer.
"""
import json
import os
import time

import jax
import numpy as np

from repro.core.graphs import edge_list, random_strongly_connected, stack_edge_lists
from repro.core.pushsum import run_pushsum_sparse, sparse_mass_invariant
from repro.core.sweeps import run_pushsum_sweep

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
JSON_PATH = os.path.join(RESULTS_DIR, "BENCH_pushsum_sweep.json")


def _bench_large_sparse(n=1024, d=8, T=64, extra_edge_prob=0.002, seed=0):
    """N=1024 agents, E << N^2, single run of the edge-list core."""
    rng = np.random.default_rng(seed)
    adj = random_strongly_connected(n, extra_edge_prob, rng)
    el = edge_list(adj)
    w = rng.normal(size=(n, d)).astype(np.float32)

    # jit once so the steady-state timing measures execution, not retrace
    run = jax.jit(lambda w_, src_, dst_: run_pushsum_sparse(
        w_, src_, dst_, T, drop_prob=0.2, B=4, record_every=T
    ))

    def go():
        final, traj = run(w, el.src, el.dst)
        jax.block_until_ready(final)
        return final, np.asarray(traj[-1])   # one frame: round T-1

    t0 = time.perf_counter()
    final, last = go()                       # trace + compile + run
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    final, last = go()                       # steady state (compiled)
    wall_us = (time.perf_counter() - t0) / T * 1e6
    err = float(np.abs(last - w.mean(0)).max())
    gap = float(np.abs(np.asarray(
        sparse_mass_invariant(final, el.src, el.valid)) - w.sum(0)).max())
    return {
        "name": f"pushsum_sparse_N{n}",
        "us_per_call": wall_us,
        "derived": f"E={el.E};E_over_N2={el.E / n**2:.4f};"
                   f"err_T{T}={err:.2e};mass_gap={gap:.1e};"
                   f"compile_s={compile_wall:.1f}",
    }


def _bench_sweep(n=256, d=4, T=300, n_graphs=2, seed=0):
    """>= 32-scenario grid in one jitted vmapped scan."""
    rng = np.random.default_rng(seed)
    adjs = [random_strongly_connected(n, 0.02, rng) for _ in range(n_graphs)]
    el = stack_edge_lists(adjs)
    w = rng.normal(size=(n, d)).astype(np.float32)
    drop_probs = [0.0, 0.3, 0.6, 0.9]
    seeds = [0, 1, 2, 3]

    t0 = time.perf_counter()
    res = run_pushsum_sweep(w, el, T, drop_probs=drop_probs, seeds=seeds, B=4)
    res.err.block_until_ready()
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = run_pushsum_sweep(w, el, T, drop_probs=drop_probs, seeds=seeds, B=4)
    res.err.block_until_ready()
    wall = time.perf_counter() - t0

    err = np.asarray(res.err)
    K = res.K
    assert K >= 32, K
    # every scenario either decays from its round-20 level or already sits
    # at the fp32 noise floor (drop=0 scenarios converge before round 20)
    decayed = bool((err[:, -1] <= np.maximum(err[:, 20], 1e-4)).all())
    return {
        "name": f"pushsum_sweep_vmap{K}",
        "us_per_call": wall / K * 1e6,       # per-scenario cost
        "derived": f"scenarios={K};single_jit=true;T={T};"
                   f"err_final_max={err[:, -1].max():.2e};"
                   f"all_decay={decayed};wall_s={wall:.2f};"
                   f"compile_s={compile_wall:.1f}",
        "scenarios": K,
        "single_jit": True,
    }


def rows():
    recs = [_bench_large_sparse(), _bench_sweep()]
    return [(r["name"], r["us_per_call"], r["derived"]) for r in recs]


if __name__ == "__main__":
    # standalone run writes the BENCH json itself; under benchmarks/run.py
    # the --json-dir flag is the single writer.
    out = rows()
    print("name,us_per_call,derived")
    for name, us, derived in out:
        print(f"{name},{us:.1f},{derived}")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump({name: {"us_per_call": us, "derived": derived}
                   for name, us, derived in out}, f, indent=1)
    print(f"# wrote {os.path.normpath(JSON_PATH)}")
