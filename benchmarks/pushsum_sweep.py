"""Sparse-core + fused-kernel + sharded-sweep benchmark.

Claims pinned:
 * the edge-list core runs N up to 131072 agents on sparse digraphs built
   directly as edge lists (``graphs.random_strongly_connected_edge_list``)
   without ever allocating an (N, N) or (N, N, d) array;
 * the per-round delivery/integration runs through the
   ``backend="xla"|"pallas"`` switch — per-step microseconds are recorded
   for both at N in {1024, 16384, 131072} (on CPU the Pallas path is
   ``interpret=True`` equivalence mode, not a fast path; the compiled
   comparison is TPU-only);
 * a >= 256-scenario grid (topology draws x drop probs x seeds) runs as ONE
   program, vmapped on a single device AND shard_map-sharded over a
   multi-device ``data`` mesh axis (`repro.core.sweeps.run_pushsum_sweep`),
   with identical results;
 * the edge-partitioned 2-D (data x graph) mesh mode
   (``graph_shards=``) runs a SINGLE N >= 1e6 scenario by cutting the
   edge list itself into per-device dst-contiguous shards and psum-ing
   boundary partials over the ``graph`` axis — per-step walls recorded,
   bit-identical to the single-device vmap emulation of the same cut;
 * consensus error decays in every scenario (Theorem 1 across the grid).

Emits name,us_per_call,derived rows via :func:`rows`; ``rows(smoke=True)``
is the fast CI subset (small N, no subprocess). The machine-readable
``BENCH_pushsum_sweep.json`` perf-trajectory artifact is merge-updated in
``results/`` when run standalone (``python -m benchmarks.pushsum_sweep``);
under ``benchmarks/run.py`` the ``--json-dir`` flag is the single writer.
"""
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np

from repro.core.graphs import (
    edge_list,
    random_strongly_connected,
    random_strongly_connected_edge_list,
    sort_by_dst,
    stack_edge_lists,
)
from repro.core.pushsum import run_pushsum_sparse, sparse_mass_invariant
from repro.core.sweeps import run_pushsum_sweep

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(REPO, "results")
JSON_PATH = os.path.join(RESULTS_DIR, "BENCH_pushsum_sweep.json")


def _bench_large_sparse(n=1024, d=8, T=64, extra_edge_prob=0.002, seed=0):
    """N=1024 agents, E << N^2, single run of the edge-list core."""
    rng = np.random.default_rng(seed)
    adj = random_strongly_connected(n, extra_edge_prob, rng)
    el = edge_list(adj)
    w = rng.normal(size=(n, d)).astype(np.float32)

    # jit once so the steady-state timing measures execution, not retrace
    run = jax.jit(lambda w_, src_, dst_: run_pushsum_sparse(
        w_, src_, dst_, T, drop_prob=0.2, B=4, record_every=T
    ))

    def go():
        final, traj = run(w, el.src, el.dst)
        jax.block_until_ready(final)
        return final, np.asarray(traj[-1])   # one frame: round T-1

    t0 = time.perf_counter()
    final, last = go()                       # trace + compile + run
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    final, last = go()                       # steady state (compiled)
    wall_us = (time.perf_counter() - t0) / T * 1e6
    err = float(np.abs(last - w.mean(0)).max())
    gap = float(np.abs(np.asarray(
        sparse_mass_invariant(final, el.src, el.valid)) - w.sum(0)).max())
    return {
        "name": f"pushsum_sparse_N{n}",
        "us_per_call": wall_us,
        "derived": f"E={el.E};E_over_N2={el.E / n**2:.4f};"
                   f"err_T{T}={err:.2e};mass_gap={gap:.1e};"
                   f"compile_s={compile_wall:.1f}",
    }


def _bytes_per_call(lowered, calls: int) -> float:
    """Per-call 'bytes accessed' from the compiled executable's
    cost_analysis (a dict on current jax, a [dict] on older builds);
    NaN when the backend doesn't report it."""
    try:
        cost = lowered.compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        return float(cost["bytes accessed"]) / calls
    except Exception:
        return float("nan")


def _bench_step_backend(n, backend, d=4, extra=2.0, seed=0, T=None,
                        policy=None):
    """Per-step cost of one backend at scale N (dst-sorted edge index).

    The graph is built directly as a sparse edge list — at N=131072 the
    dense adjacency alone would be 17 GB. On CPU the Pallas backend runs
    ``interpret=True`` (the equivalence mode CI tests), so its numbers
    measure the interpreter, not the kernel; on TPU the same call compiles.

    ``policy`` ("bf16") switches the scan-carried state to the reduced
    storage dtype (:mod:`repro.core.precision`) and suffixes the row name;
    every row also records the compiled program's per-step traffic
    (``bytes_per_step``, from ``cost_analysis`` — on CPU this includes the
    fp32 in-body accumulator transients XLA would fuse away on the TPU
    target) and the analytic persistent-state budget (``budget_bytes``,
    :func:`repro.statics.memory.pushsum_step_bytes` at the policy's
    storage width) so the storage-bandwidth claim is checked on the
    artifact against the same model ``repro.statics budget`` proves.
    """
    from repro.statics.memory import pushsum_step_bytes
    rng = np.random.default_rng(seed)
    el = random_strongly_connected_edge_list(n, extra, rng)   # sorted by dst
    w = rng.normal(size=(n, d)).astype(np.float32)
    if T is None:   # interpret-mode pallas steps are expensive on CPU
        T = 16 if backend == "xla" else 2

    run = jax.jit(lambda w_, src_, dst_: run_pushsum_sparse(
        w_, src_, dst_, T, drop_prob=0.2, B=4, record_every=T,
        backend=backend, policy=policy, dst_sorted=True,
    ))

    def go():
        final, _ = run(w, el.src, el.dst)
        jax.block_until_ready(final)
        return final

    t0 = time.perf_counter()
    final = go()
    compile_wall = time.perf_counter() - t0
    bytes_step = _bytes_per_call(run.lower(w, el.src, el.dst), T)
    t0 = time.perf_counter()
    final = go()
    step_us = (time.perf_counter() - t0) / T * 1e6
    gap = float(np.abs(np.asarray(
        sparse_mass_invariant(final, el.src, el.valid)) - w.sum(0)).max())
    mode = ("interpret" if backend == "pallas"
            and jax.default_backend() != "tpu" else "compiled")
    tag = "" if policy is None else f"_{policy}"
    pol = "" if policy is None else f"policy={policy};"
    budget = pushsum_step_bytes(n, int(el.E), d=d, policy=policy)
    return {
        "name": f"pushsum_step_{backend}{tag}_N{n}",
        "us_per_call": step_us,
        "derived": f"E={el.E};d={d};T={T};backend={backend};mode={mode};"
                   f"{pol}bytes_per_step={bytes_step:.0f};"
                   f"budget_bytes={budget};"
                   f"mass_gap={gap:.1e};compile_s={compile_wall:.1f}",
    }


def _bench_sweep(n=256, d=4, T=300, n_graphs=2, seed=0):
    """>= 32-scenario grid in one jitted vmapped scan."""
    rng = np.random.default_rng(seed)
    adjs = [random_strongly_connected(n, 0.02, rng) for _ in range(n_graphs)]
    el, _, _ = sort_by_dst(stack_edge_lists(adjs))
    w = rng.normal(size=(n, d)).astype(np.float32)
    drop_probs = [0.0, 0.3, 0.6, 0.9]
    seeds = [0, 1, 2, 3]

    t0 = time.perf_counter()
    res = run_pushsum_sweep(w, el, T, drop_probs=drop_probs, seeds=seeds, B=4)
    res.err.block_until_ready()
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = run_pushsum_sweep(w, el, T, drop_probs=drop_probs, seeds=seeds, B=4)
    res.err.block_until_ready()
    wall = time.perf_counter() - t0

    err = np.asarray(res.err)
    K = res.K
    assert K >= 32, K
    # every scenario either decays from its round-20 level or already sits
    # at the fp32 noise floor (drop=0 scenarios converge before round 20)
    decayed = bool((err[:, -1] <= np.maximum(err[:, 20], 1e-4)).all())
    return {
        "name": f"pushsum_sweep_vmap{K}",
        "us_per_call": wall / K * 1e6,       # per-scenario cost
        "derived": f"scenarios={K};single_jit=true;T={T};"
                   f"err_final_max={err[:, -1].max():.2e};"
                   f"all_decay={decayed};wall_s={wall:.2f};"
                   f"compile_s={compile_wall:.1f}",
        "scenarios": K,
        "single_jit": True,
    }


def _bench_sharded_sweep(n=128, d=3, T=100, devices=4, seed=0):
    """K=256 scenarios in ONE call: single-device vmap vs mesh-sharded.

    Runs in a subprocess so the fake multi-device CPU mesh
    (``--xla_force_host_platform_device_count``) doesn't leak into this
    process's jax runtime (same pattern as tests/test_distributed.py). On a
    real multi-host fleet the same ``mesh=`` argument shards the scenario
    batch across accelerators; the fake-device walls recorded here pin the
    single-program/sharded semantics, not a speedup (the devices share one
    CPU core).
    """
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import json, time
        import numpy as np
        import jax
        from repro.core.graphs import (
            random_strongly_connected, sort_by_dst, stack_edge_lists)
        from repro.core.sweeps import run_pushsum_sweep
        from repro.launch import compat

        rng = np.random.default_rng({seed})
        adjs = [random_strongly_connected({n}, 0.03, rng) for _ in range(2)]
        el, _, _ = sort_by_dst(stack_edge_lists(adjs))
        w = rng.normal(size=({n}, {d})).astype(np.float32)
        drops = [0.0, 0.2, 0.4, 0.6, 0.7, 0.8, 0.85, 0.9]
        seeds = list(range(16))          # K = 2 * 8 * 16 = 256

        def timed(**kw):
            t0 = time.perf_counter()
            r = run_pushsum_sweep(w, el, {T}, drop_probs=drops, seeds=seeds,
                                  B=4, **kw)
            r.err.block_until_ready()
            compile_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            r = run_pushsum_sweep(w, el, {T}, drop_probs=drops, seeds=seeds,
                                  B=4, **kw)
            r.err.block_until_ready()
            return r, time.perf_counter() - t0, compile_s

        r1, single_s, c1 = timed()
        mesh = compat.make_mesh(({devices},), ("data",))
        r2, sharded_s, c2 = timed(mesh=mesh)
        err = np.abs(np.asarray(r2.err) - np.asarray(r1.err)).max()
        final = np.asarray(r2.err)[:, -1]
        print(json.dumps({{
            "K": int(r2.K), "single_s": single_s, "sharded_s": sharded_s,
            "compile_single_s": c1, "compile_sharded_s": c2,
            "shard_vs_vmap_err": float(err),
            "err_final_max": float(final.max()),
        }}))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    try:
        out = subprocess.run([sys.executable, "-c", prog],
                             capture_output=True, text=True, timeout=900,
                             env=env, cwd=REPO)
        failure = out.stderr.strip()[-160:] if out.returncode else None
    except subprocess.TimeoutExpired:
        failure = "timeout_900s"
    if failure is not None:
        # degrade to a NaN row so the other modules' rows survive; the
        # json merge skips NaN and --check ignores it
        return {
            "name": "pushsum_sweep_sharded256",
            "us_per_call": float("nan"),
            "derived": "subprocess_failed;" + failure,
        }
    res = json.loads(
        [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    )
    return {
        "name": f"pushsum_sweep_sharded{res['K']}",
        "us_per_call": res["sharded_s"] / res["K"] * 1e6,
        "derived": f"scenarios={res['K']};devices={devices};single_jit=true;"
                   f"sharded_wall_s={res['sharded_s']:.2f};"
                   f"single_dev_wall_s={res['single_s']:.2f};"
                   f"shard_vs_vmap_err={res['shard_vs_vmap_err']:.1e};"
                   f"err_final_max={res['err_final_max']:.2e};"
                   f"compile_s={res['compile_sharded_s']:.1f}",
        "scenarios": res["K"],
        "single_jit": True,
    }


def _bench_edge_sharded(n=1 << 20, d=1, T=4, devices=8, extra=1.0, seed=0,
                        policy=None, halo="psum"):
    """ONE million-agent scenario on the 2-D (data x graph) mesh.

    The graph (E ~ 2e6 edges) is cut into ``devices`` dst-contiguous edge
    shards (`graphs.partition_edge_list`); each fake CPU device runs the
    unchanged per-shard step and boundary-node receiver partials are
    psum'd over the mesh ``graph`` axis. Same subprocess pattern as
    :func:`_bench_sharded_sweep` so the forced device count doesn't leak.
    The subprocess also pins the bit-identity contract at small N: the
    shard_map mesh run must match the single-device
    ``vmap(axis_name=)`` emulation of the same cut EXACTLY (same psum
    order on every device — see sweeps.run_pushsum_sweep's docstring).
    Fake devices share one CPU, so the wall pins semantics + per-device
    memory shape, not a speedup.

    ``policy``/``halo`` thread the storage dtype and the halo-collective
    lowering through (``policy="bf16", halo="scatter"`` is the
    bandwidth-optimized configuration: bf16 state + reduce-scatter/
    all-gather halo whose re-broadcast leg rides the storage dtype).
    """
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import json, time
        import numpy as np
        import jax
        from repro.core.graphs import random_strongly_connected_edge_list
        from repro.core.sweeps import run_pushsum_sweep
        from repro.distributed.sharding import sweep_mesh

        mesh = sweep_mesh(1, {devices})      # (data=1, graph={devices})
        pol = dict(policy={policy!r}, halo={halo!r})

        # small-N identity: 2-D mesh shard_map vs single-device emulation
        rng = np.random.default_rng({seed})
        el_s = random_strongly_connected_edge_list(256, 2.0, rng)
        w_s = rng.normal(size=(256, {d})).astype(np.float32)
        kw = dict(drop_probs=[0.0, 0.3], seeds=[0, 1], B=4,
                  graph_shards={devices}, **pol)
        r_emu = run_pushsum_sweep(w_s, el_s, 30, **kw)
        r_mesh = run_pushsum_sweep(w_s, el_s, 30, mesh=mesh, **kw)
        ident = float(np.abs(
            np.asarray(r_mesh.err) - np.asarray(r_emu.err)).max())

        # the N >= 1e6 scenario
        rng = np.random.default_rng({seed})
        el = random_strongly_connected_edge_list({n}, {extra}, rng)
        w = rng.normal(size=({n}, {d})).astype(np.float32)

        def once():
            t0 = time.perf_counter()
            r = run_pushsum_sweep(w, el, {T}, drop_probs=[0.2], seeds=[0],
                                  B=4, mesh=mesh, graph_shards={devices},
                                  **pol)
            r.err.block_until_ready()
            return r, time.perf_counter() - t0

        r, compile_s = once()                # trace + compile + run
        r, wall = once()                     # steady state
        err = np.asarray(r.err)
        gap = float(np.abs(np.asarray(r.mass_gap)).max())
        print(json.dumps({{
            "E": int(el.E), "wall_s": wall, "compile_s": compile_s,
            "err_final": float(err[:, -1].max()),
            "mass_gap": gap,
            "mesh_vs_emul_err": ident,
        }}))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    try:
        out = subprocess.run([sys.executable, "-c", prog],
                             capture_output=True, text=True, timeout=900,
                             env=env, cwd=REPO)
        failure = out.stderr.strip()[-160:] if out.returncode else None
    except subprocess.TimeoutExpired:
        failure = "timeout_900s"
    tag = "" if policy is None else f"_{policy}"
    name = f"pushsum_edge_sharded{tag}_N{n}"
    if failure is not None:
        return {
            "name": name,
            "us_per_call": float("nan"),
            "derived": "subprocess_failed;" + failure,
        }
    res = json.loads(
        [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    )
    from repro.analysis.roofline import pushsum_halo_wire_bytes
    from repro.core.precision import resolve_policy
    from repro.statics.memory import pushsum_sharded_step_bytes

    budget = pushsum_sharded_step_bytes(n, res["E"], d=d, n_shards=devices,
                                        policy=policy)
    sb = 4 if policy is None else resolve_policy(policy).storage_bytes
    wire = pushsum_halo_wire_bytes(n, d, devices, variant=halo,
                                   storage_bytes=sb)
    pol = "" if policy is None else f"policy={policy};halo={halo};"
    return {
        "name": name,
        "us_per_call": res["wall_s"] / T * 1e6,   # per-step cost
        "derived": f"E={res['E']};shards={devices};d={d};T={T};"
                   f"devices={devices};mesh=1x{devices};{pol}"
                   f"budget_bytes={budget};halo_wire_bytes={wire:.0f};"
                   f"mesh_vs_emul_err={res['mesh_vs_emul_err']:.1e};"
                   f"err_final={res['err_final']:.2e};"
                   f"mass_gap={res['mass_gap']:.1e};"
                   f"compile_s={res['compile_s']:.1f}",
    }


def _bench_edge_sharded_smoke(n=256, d=2, T=50, seed=0,
                              policy=None, halo="psum"):
    """In-process 2-shard smoke of the edge-partitioned mode.

    Only meaningful when the HOST exposes >= 2 devices (the multidevice CI
    lane forces 8 fake CPU devices); a single-device host emits an explicit
    ``skipped=`` row — kept in the JSON artifact as ``us_per_call: null``
    and announced by run.py --check as ``# SKIP`` — instead of silently
    measuring nothing or crashing on mesh construction. ``policy``/``halo``
    select the storage policy and halo collective, like the full-size
    sharded bench — the bf16+scatter smoke row is what the multidevice CI
    lane asserts on (mesh == emulation must hold bit-exactly under the
    reduced-precision state too).
    """
    n_dev = jax.device_count()
    tag = "" if policy is None else f"_{policy}"
    name = f"pushsum_edge_smoke{tag}_N{n}"
    if n_dev < 2:
        return {
            "name": name,
            "us_per_call": float("nan"),
            "derived": f"skipped=single_device_host;devices={n_dev}",
        }
    from repro.distributed.sharding import sweep_mesh

    S = 2
    rng = np.random.default_rng(seed)
    el = random_strongly_connected_edge_list(n, 2.0, rng)
    w = rng.normal(size=(n, d)).astype(np.float32)
    mesh = sweep_mesh(1, S, devices=jax.devices()[:S])
    kw = dict(drop_probs=[0.0, 0.4], seeds=[0, 1], B=4, graph_shards=S,
              policy=policy, halo=halo)
    r_emu = run_pushsum_sweep(w, el, T, **kw)
    t0 = time.perf_counter()
    r_mesh = run_pushsum_sweep(w, el, T, mesh=mesh, **kw)
    r_mesh.err.block_until_ready()
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    r_mesh = run_pushsum_sweep(w, el, T, mesh=mesh, **kw)
    r_mesh.err.block_until_ready()
    step_us = (time.perf_counter() - t0) / T * 1e6
    ident = float(np.abs(
        np.asarray(r_mesh.err) - np.asarray(r_emu.err)).max())
    pol_tag = "" if policy is None else f"policy={policy};halo={halo};"
    return {
        "name": name,
        "us_per_call": step_us,
        "derived": f"E={el.E};shards={S};d={d};T={T};devices={n_dev};"
                   f"{pol_tag}"
                   f"mesh_vs_emul_err={ident:.1e};"
                   f"err_final={np.asarray(r_mesh.err)[:, -1].max():.2e};"
                   f"compile_s={compile_wall:.1f}",
    }


def _bench_burst_sweep(smoke: bool = False):
    """Burst-length axis of the unified fault plane: a Gilbert-Elliott
    ladder (mean burst 1, 4, 16 rounds at a fixed 30% stationary bad
    fraction) rides the sweep's fault dimension — one compiled program,
    fault realizations crossed fault-minor against (drop x seed). The
    derived string records the final consensus error per burst length
    next to the degenerate (no-fault) reference rows, which must match
    the plain sweep (regression-tested in tests/test_faults.py)."""
    from repro.core.faults import gilbert_elliott_model, make_fault_model

    n, d, T = (256, 3, 120) if smoke else (1024, 4, 300)
    bursts = (1, 4, 16)
    rng = np.random.default_rng(0)
    el = random_strongly_connected_edge_list(n, 2.0, rng)
    w = rng.normal(size=(n, d)).astype(np.float32)
    faults = [make_fault_model()] + [
        gilbert_elliott_model(float(L), 0.3) for L in bursts]
    nf = len(faults)
    kw = dict(drop_probs=[0.1, 0.4], seeds=[0, 1], B=4, faults=faults)

    def go():
        res = run_pushsum_sweep(w, el, T, **kw)
        jax.block_until_ready(res.err)
        return res

    t0 = time.perf_counter()
    res = go()
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = go()
    wall = time.perf_counter() - t0
    k = res.err.shape[0]
    final = np.asarray(res.err)[:, -1]
    per_fault = [float(final[i::nf].max()) for i in range(nf)]
    tags = ";".join(f"err_L{L}={e:.2e}"
                    for L, e in zip((0,) + bursts, per_fault))
    return {
        "name": "pushsum_sweep_burst",
        "us_per_call": wall / k * 1e6,
        "derived": f"E={el.E};scenarios={k};T={T};bad_frac=0.3;"
                   f"bursts=0,{','.join(map(str, bursts))};{tags};"
                   f"compile_s={compile_wall:.1f}",
    }


def rows(smoke: bool = False):
    if smoke:
        recs = [
            _bench_large_sparse(),
            _bench_step_backend(1024, "xla"),
            _bench_step_backend(1024, "xla", policy="bf16"),
            _bench_step_backend(1024, "pallas"),
            _bench_edge_sharded_smoke(),
            _bench_edge_sharded_smoke(policy="bf16", halo="scatter"),
            _bench_burst_sweep(smoke=True),
        ]
    else:
        recs = [_bench_large_sparse()]
        for n in (1024, 16384, 131072):
            recs.append(_bench_step_backend(n, "xla"))
            recs.append(_bench_step_backend(n, "pallas"))
        recs.append(_bench_step_backend(131072, "xla", policy="bf16"))
        recs.append(_bench_sweep())
        recs.append(_bench_sharded_sweep())
        recs.append(_bench_edge_sharded())
        recs.append(_bench_edge_sharded(policy="bf16", halo="scatter"))
        recs.append(_bench_burst_sweep())
    return [(r["name"], r["us_per_call"], r["derived"]) for r in recs]


if __name__ == "__main__":
    # standalone run merge-updates the BENCH json itself; under
    # benchmarks/run.py the --json-dir flag is the single writer.
    out = rows(smoke="--smoke" in sys.argv)
    print("name,us_per_call,derived")
    for name, us, derived in out:
        print(f"{name},{us:.1f},{derived}")
    from benchmarks import merge_bench_json

    os.makedirs(RESULTS_DIR, exist_ok=True)
    merge_bench_json(JSON_PATH, out)
    print(f"# wrote {os.path.normpath(JSON_PATH)}")
