"""Theorem 3 benchmarks: Byzantine-resilient learning.

Three claim families:
 * accuracy — fraction of normal agents deciding theta* at T per attack
   strategy, with the paper's trim filter vs the unfiltered baseline, plus
   the pairwise-vs-one-vs-rest ablation (``thm3_*`` rows);
 * per-step cost of the sparse neighbor-list gossip core at
   N in {64, 512, 4096} through the ``backend="xla"|"pallas"`` switch
   (``byzantine_step_*`` rows), against the dense (N, N, m, m) broadcast
   oracle where it still fits (the speedup is recorded in ``derived``; at
   N = 4096 the dense path would materialize ~0.6 GB per sort input and is
   skipped — which is the point of the sparse core);
 * a (topology x F x seed) grid compiled ONCE as a single vmapped scan
   (``byzantine_grid_*`` row; :func:`repro.core.sweeps.run_byzantine_grid`).

On CPU the Pallas rows run ``interpret=True`` equivalence mode (tagged
``mode=interpret``; the perf gate skips them) — the compiled comparison is
TPU-only, as with the push-sum kernel rows.
"""
import time

import jax
import numpy as np

from repro.core.graphs import make_hierarchy
from repro.core.signals import make_confused_model
from repro.core.byzantine import (
    ByzantineConfig, make_byzantine_scan, run_byzantine_learning,
    run_byzantine_learning_ovr,
)
from repro.core.sweeps import run_byzantine_grid
from repro.core import attacks


def _accuracy_rows():
    out = []
    topo = make_hierarchy([7, 7, 7, 7], topology="complete", seed=0)
    model = make_confused_model(N=topo.N, m=3, truth=0, confusion=0.0, seed=1)
    byz = (2, 9)
    T = 500
    for name in ("large_value", "sign_flip", "random_noise",
                 "truth_suppression", "extreme_pull"):
        atk = (attacks.ATTACKS[name](0) if name == "truth_suppression"
               else attacks.ATTACKS[name]())
        cfg = ByzantineConfig(topo=topo, F=2, byz=byz, gamma_period=10,
                              attack=atk)
        t0 = time.perf_counter()
        res = run_byzantine_learning(model, cfg, T=T, seed=0)
        wall = (time.perf_counter() - t0) / T * 1e6
        dec = np.asarray(res.decisions[-1])
        bm = cfg.byz_mask()
        acc = float((dec[~bm] == model.truth).mean())
        out.append((f"thm3_byz_{name}", wall, f"normal_acc={acc:.3f}"))
    # unfiltered baseline under the strongest attack
    cfg = ByzantineConfig(topo=topo, F=0, byz=byz, gamma_period=10,
                          attack=attacks.truth_suppression(0, magnitude=1e4))
    t0 = time.perf_counter()
    res = run_byzantine_learning(model, cfg, T=300, seed=0)
    wall = (time.perf_counter() - t0) / 300 * 1e6
    dec = np.asarray(res.decisions[-1])
    bm = np.zeros(topo.N, bool); bm[list(byz)] = True
    acc = float((dec[~bm] == model.truth).mean())
    out.append(("thm3_unfiltered_baseline", wall, f"normal_acc={acc:.3f}"))

    # ablation: one-vs-rest (m dynamics) vs the paper's pairwise (m(m-1))
    topo5 = make_hierarchy([7] * 5, topology="complete", seed=2)
    model5 = make_confused_model(N=topo5.N, m=4, truth=1, confusion=0.0,
                                 seed=2)
    for name, runner in (("pairwise", run_byzantine_learning),
                         ("one_vs_rest", run_byzantine_learning_ovr)):
        cfg = ByzantineConfig(topo=topo5, F=2, byz=(2, 9), gamma_period=10,
                              attack=attacks.truth_suppression(1))
        t0 = time.perf_counter()
        res = runner(model5, cfg, T=400, seed=0)
        wall = (time.perf_counter() - t0) / 400 * 1e6
        dec = np.asarray(res.decisions[-1])
        bm = cfg.byz_mask()
        acc = float((dec[~bm] == 1).mean())
        out.append((f"thm3_ablation_{name}", wall, f"normal_acc={acc:.3f}"))
    return out


def _step_setup(N):
    """N/8 complete 8-agent networks — deg_max stays 7 at every scale."""
    topo = make_hierarchy([8] * (N // 8), topology="complete", seed=0)
    model = make_confused_model(N=N, m=3, truth=0, confusion=0.0, seed=1)
    cfg = ByzantineConfig(topo=topo, F=2, byz=(2, 9), gamma_period=10,
                          attack=attacks.large_value())
    return model, cfg


def _time_scan(model, cfg, T, **scan_kwargs):
    run = jax.jit(make_byzantine_scan(model, cfg, T, store="final",
                                      **scan_kwargs))
    key = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    jax.block_until_ready(run(key))
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    jax.block_until_ready(run(key))
    return (time.perf_counter() - t0) / T * 1e6, compile_wall


def _step_rows(smoke: bool):
    """byzantine_step_{xla,pallas}_N{64,512,4096} + the dense comparison."""
    out = []
    sizes = (64, 512) if smoke else (64, 512, 4096)
    m = 3
    for N in sizes:
        model, cfg = _step_setup(N)
        dense_bytes = N * N * m * m * 4
        if N <= 512:
            dense_us, _ = _time_scan(model, cfg, T=30, core="dense")
            dense_tag = f"dense_us={dense_us:.1f}"
        else:
            # (N, N, m, m) fp32 sort input alone is ~0.6 GB at N=4096:
            # the dense oracle is exactly what the sparse core retires
            dense_us = None
            dense_tag = f"dense=skipped;dense_bytes={dense_bytes:.1e}"
        xla_us, compile_s = _time_scan(model, cfg, T=30, core="sparse",
                                       backend="xla")
        speedup = (f";speedup_vs_dense={dense_us / xla_us:.1f}x"
                   if dense_us is not None else "")
        out.append((
            f"byzantine_step_xla_N{N}", xla_us,
            f"deg_max=7;F=2;m={m};{dense_tag}{speedup};"
            f"compile_s={compile_s:.1f}",
        ))
        mode = "interpret" if jax.default_backend() != "tpu" else "compiled"
        T_p = 4 if mode == "interpret" else 30
        pallas_us, compile_s = _time_scan(model, cfg, T=T_p, core="sparse",
                                          backend="pallas")
        out.append((
            f"byzantine_step_pallas_N{N}", pallas_us,
            f"deg_max=7;F=2;m={m};mode={mode};compile_s={compile_s:.1f}",
        ))
    return out


def _grid_row(smoke: bool):
    """topology x F x seed grid: one trace, one compiled program."""
    model = make_confused_model(N=15, m=3, truth=0, confusion=0.0, seed=0)
    atk = attacks.large_value()
    topos = [make_hierarchy([5, 5, 5], topology="ring+", extra_edge_prob=0.9,
                            seed=s) for s in range(3)]
    cfgs = []
    for topo in topos:
        cfgs.append(ByzantineConfig(topo=topo, F=0, byz=(), gamma_period=4,
                                    attack=atk))
        cfgs.append(ByzantineConfig(topo=topo, F=1, byz=(1,), gamma_period=4,
                                    attack=atk))
    seeds = list(range(2 if smoke else 8))
    T = 50 if smoke else 200

    def go():
        res = run_byzantine_grid(model, cfgs, T, seeds, store="decisions")
        jax.block_until_ready(res.decisions)
        return res

    t0 = time.perf_counter()
    res = go()
    compile_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = go()
    wall = time.perf_counter() - t0
    dec = np.asarray(res.decisions)[:, -1]          # (K, N) final decisions
    byz_cols = np.asarray([list(cfgs[int(c)].byz) for c in res.cfg],
                          dtype=object)
    accs = []
    for k in range(res.K):
        bm = np.zeros(15, bool)
        bm[list(byz_cols[k])] = True
        accs.append(float((dec[k][~bm] == model.truth).mean()))
    return (
        f"byzantine_grid_topoxF{res.K}", wall / res.K * 1e6,
        f"scenarios={res.K};topos=3;F=0|1;seeds={len(seeds)};T={T};"
        f"single_jit=true;acc_mean={np.mean(accs):.3f};"
        f"compile_s={compile_wall:.1f}",
    )


def rows(smoke: bool = False):
    out = [] if smoke else _accuracy_rows()
    out.extend(_step_rows(smoke))
    out.append(_grid_row(smoke))
    return out
