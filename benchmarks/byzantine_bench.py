"""Theorem 3 benchmark: Byzantine-resilient learning, attack x F sweep.

Derived metric: fraction of normal agents deciding theta* at T, per attack
strategy — with the paper's trim filter vs the unfiltered baseline.
"""
import time

import numpy as np

from repro.core.graphs import make_hierarchy
from repro.core.signals import make_confused_model
from repro.core.byzantine import (
    ByzantineConfig, run_byzantine_learning, run_byzantine_learning_ovr,
)
from repro.core import attacks


def rows():
    out = []
    topo = make_hierarchy([7, 7, 7, 7], topology="complete", seed=0)
    model = make_confused_model(N=topo.N, m=3, truth=0, confusion=0.0, seed=1)
    byz = (2, 9)
    T = 500
    for name in ("large_value", "sign_flip", "random_noise",
                 "truth_suppression", "extreme_pull"):
        atk = (attacks.ATTACKS[name](0) if name == "truth_suppression"
               else attacks.ATTACKS[name]())
        cfg = ByzantineConfig(topo=topo, F=2, byz=byz, gamma_period=10,
                              attack=atk)
        t0 = time.perf_counter()
        res = run_byzantine_learning(model, cfg, T=T, seed=0)
        wall = (time.perf_counter() - t0) / T * 1e6
        dec = np.asarray(res.decisions[-1])
        bm = cfg.byz_mask()
        acc = float((dec[~bm] == model.truth).mean())
        out.append((f"thm3_byz_{name}", wall, f"normal_acc={acc:.3f}"))
    # unfiltered baseline under the strongest attack
    cfg = ByzantineConfig(topo=topo, F=0, byz=byz, gamma_period=10,
                          attack=attacks.truth_suppression(0, magnitude=1e4))
    t0 = time.perf_counter()
    res = run_byzantine_learning(model, cfg, T=300, seed=0)
    wall = (time.perf_counter() - t0) / 300 * 1e6
    dec = np.asarray(res.decisions[-1])
    bm = np.zeros(topo.N, bool); bm[list(byz)] = True
    acc = float((dec[~bm] == model.truth).mean())
    out.append(("thm3_unfiltered_baseline", wall, f"normal_acc={acc:.3f}"))

    # ablation: one-vs-rest (m dynamics) vs the paper's pairwise (m(m-1))
    topo5 = make_hierarchy([7] * 5, topology="complete", seed=2)
    model5 = make_confused_model(N=topo5.N, m=4, truth=1, confusion=0.0,
                                 seed=2)
    for name, runner in (("pairwise", run_byzantine_learning),
                         ("one_vs_rest", run_byzantine_learning_ovr)):
        cfg = ByzantineConfig(topo=topo5, F=2, byz=(2, 9), gamma_period=10,
                              attack=attacks.truth_suppression(1))
        t0 = time.perf_counter()
        res = runner(model5, cfg, T=400, seed=0)
        wall = (time.perf_counter() - t0) / 400 * 1e6
        dec = np.asarray(res.decisions[-1])
        bm = cfg.byz_mask()
        acc = float((dec[~bm] == 1).mean())
        out.append((f"thm3_ablation_{name}", wall, f"normal_acc={acc:.3f}"))
    return out
