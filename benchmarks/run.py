"""Benchmark harness — one module per paper claim (the paper has no
numbered tables; each Theorem/Remark gets a benchmark).

Prints ``name,us_per_call,derived`` CSV rows, plus a §Roofline summary from
the latest dry-run results JSON if present (results/dryrun_single.json).

With ``--json-dir DIR`` each module additionally writes a machine-readable
``BENCH_<tag>.json`` (name -> {us_per_call, derived}) next to the CSV
stream so the perf trajectory is tracked across PRs:

    python -m benchmarks.run --json-dir results          # all modules
    python -m benchmarks.run pushsum_sweep               # one module, CSV
"""
import argparse
import json
import os

from . import consensus_rate, social_learning, byzantine_bench, gamma_sweep
from . import aggregators_bench, pushsum_sweep

MODULES = [
    ("thm1", consensus_rate),
    ("thm2", social_learning),
    ("thm3", byzantine_bench),
    ("remark3", gamma_sweep),
    ("aggregators", aggregators_bench),
    ("pushsum_sweep", pushsum_sweep),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("only", nargs="?", default=None,
                    help="run a single module tag (thm1, ..., pushsum_sweep)")
    ap.add_argument("--json-dir", default=None,
                    help="also write BENCH_<tag>.json per module here")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for tag, mod in MODULES:
        if args.only and tag != args.only:
            continue
        rows = list(mod.rows())
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}", flush=True)
        if args.json_dir:
            os.makedirs(args.json_dir, exist_ok=True)
            path = os.path.join(args.json_dir, f"BENCH_{tag}.json")
            with open(path, "w") as f:
                json.dump({name: {"us_per_call": us, "derived": derived}
                           for name, us, derived in rows}, f, indent=1)

    path = os.path.join(os.path.dirname(__file__), "..",
                        "results", "dryrun_single.json")
    if os.path.exists(path) and not args.only:
        with open(path) as f:
            recs = json.load(f)
        ok = [r for r in recs if r.get("ok")]
        print(f"# dry-run roofline summary ({len(ok)} combos):")
        for r in ok:
            t = r["roofline"]
            print(
                f"roofline_{r['arch']}_{r['shape']},"
                f"{t['bound_step_time_s']*1e6:.1f},"
                f"dom={t['dominant']};useful={t['useful_flop_ratio']:.2f}"
            )


if __name__ == "__main__":
    main()
