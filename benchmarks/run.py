"""Benchmark harness — one module per paper claim (the paper has no
numbered tables; each Theorem/Remark gets a benchmark).

Prints ``name,us_per_call,derived`` CSV rows, plus a §Roofline summary from
the latest dry-run results JSON if present (results/dryrun_single.json).
"""
import json
import os
import sys

from . import consensus_rate, social_learning, byzantine_bench, gamma_sweep
from . import aggregators_bench

MODULES = [
    ("thm1", consensus_rate),
    ("thm2", social_learning),
    ("thm3", byzantine_bench),
    ("remark3", gamma_sweep),
    ("aggregators", aggregators_bench),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for tag, mod in MODULES:
        if only and tag != only:
            continue
        for name, us, derived in mod.rows():
            print(f"{name},{us:.1f},{derived}", flush=True)

    path = os.path.join(os.path.dirname(__file__), "..",
                        "results", "dryrun_single.json")
    if os.path.exists(path) and not only:
        with open(path) as f:
            recs = json.load(f)
        ok = [r for r in recs if r.get("ok")]
        print(f"# dry-run roofline summary ({len(ok)} combos):")
        for r in ok:
            t = r["roofline"]
            print(
                f"roofline_{r['arch']}_{r['shape']},"
                f"{t['bound_step_time_s']*1e6:.1f},"
                f"dom={t['dominant']};useful={t['useful_flop_ratio']:.2f}"
            )


if __name__ == "__main__":
    main()
