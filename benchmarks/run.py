"""Benchmark harness — one module per paper claim (the paper has no
numbered tables; each Theorem/Remark gets a benchmark).

Prints ``name,us_per_call,derived`` CSV rows, plus a §Roofline summary from
the latest dry-run results JSON if present (results/dryrun_single.json).

With ``--json-dir DIR`` each module additionally merge-updates a
machine-readable ``BENCH_<tag>.json`` (name -> {us_per_call, derived})
next to the CSV stream so the perf trajectory is tracked across PRs —
existing keys not re-measured in this invocation (e.g. a ``--smoke`` or
single-module run) are preserved, not clobbered:

    python -m benchmarks.run --json-dir results          # all modules
    python -m benchmarks.run pushsum_sweep               # one module, CSV
    python -m benchmarks.run --smoke --json-dir results  # fast CI subset

``--check PATH`` compares the freshly measured rows against the recorded
baseline (a BENCH_*.json file, or a directory whose BENCH_*.json files are
merged — the CI form) and exits non-zero if any shared name's
``us_per_call`` regressed by more than 25% — the perf gate:

    python -m benchmarks.run pushsum_sweep --smoke \\
        --check results/BENCH_pushsum_sweep.json
    python -m benchmarks.run --smoke --check results --json-dir results
"""
import argparse
import glob
import inspect
import json
import os
import sys

from . import hps_bench, social_learning, byzantine_bench, gamma_sweep
from . import aggregators_bench, pushsum_sweep, compile_cache
from . import merge_bench_json

MODULES = [
    ("hps", hps_bench),
    ("social", social_learning),
    ("byzantine", byzantine_bench),
    ("remark3", gamma_sweep),
    ("aggregators", aggregators_bench),
    ("pushsum_sweep", pushsum_sweep),
    # last: its jax.clear_caches() must not cost the other modules their
    # warm jits mid-run
    ("compile", compile_cache),
]

REGRESSION_FACTOR = 1.25


def _module_rows(mod, smoke: bool):
    """Call mod.rows(), passing smoke= only to modules that support it."""
    if smoke and "smoke" in inspect.signature(mod.rows).parameters:
        return list(mod.rows(smoke=True))
    return list(mod.rows())


def _check_regressions(baseline_path: str, baseline: dict,
                       measured: dict[str, tuple[float, str]],
                       factor: float = REGRESSION_FACTOR) -> int:
    """Compare measured us_per_call against the recorded baseline; return
    the number of >factor regressions (default the 25% gate). Rows present
    in the run but absent from the baseline are announced with a ``# NEW``
    line (so a fault-axis or other freshly-added row is visible in the gate
    output the first time it appears) but never counted as regressions.
    Skipped silently:
    names absent from the measured side,
    NaN rows, explicitly-skipped rows (``derived`` starting ``skipped=``,
    announced with a ``# SKIP`` line so the gate output shows what was not
    measured and why), and rows whose derived tag says ``mode=interpret`` —
    interpreter timings measure the Pallas interpreter, not the kernel,
    and jitter far beyond the gate budget.

    A baseline that shares NO row name with the measured set is a hard
    failure, not a pass: the gate would otherwise compare nothing and
    report success (renamed benchmarks, or --check pointed at the wrong
    artifacts). Keyed on the name intersection — NOT on the checked count,
    which legitimately drops to zero when every overlapping row is
    interpret-mode (the CPU CI lane)."""
    if not (set(baseline) & set(measured)):
        print(f"# perf check vs {baseline_path}: baseline holds "
              f"{len(baseline)} row(s) but NONE match the {len(measured)} "
              "measured name(s) — the gate compared nothing (renamed "
              "benchmarks? wrong --check path?)")
        return 1
    bad = checked = 0
    for name, (us, derived) in measured.items():
        if derived.startswith("skipped="):
            # explicit skip (e.g. sharded bench on a single-device host):
            # say so rather than silently dropping the row from the gate
            print(f"# SKIP {name}: {derived}")
            continue
        if name not in baseline:
            print(f"# NEW {name}: {us:.1f}us (no baseline row)")
            continue
        old = baseline[name].get("us_per_call")
        if old is None or not (old == old) or not (us == us):  # skip NaN
            continue
        if "mode=interpret" in derived:
            continue
        if "gate=off" in derived:
            # compile-time rows: XLA + disk wall, jitters beyond any
            # reasonable gate budget
            continue
        checked += 1
        if us > old * factor:
            print(f"# REGRESSION {name}: {us:.1f}us > "
                  f"{factor:.2f} * baseline {old:.1f}us")
            bad += 1
    if bad == 0:
        print(f"# perf check vs {baseline_path}: {checked} rows checked, "
              f"no >{(factor - 1) * 100:.0f}% regressions")
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("only", nargs="?", default=None,
                    help="run a single module tag (hps, social, ..., "
                         "pushsum_sweep)")
    ap.add_argument("--json-dir", default=None,
                    help="merge-update BENCH_<tag>.json per module here")
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset for CI / verify flows (modules that "
                         "support rows(smoke=True); others run as usual)")
    ap.add_argument("--check", default=None, metavar="PATH",
                    help="exit non-zero if any measured us_per_call "
                         "regresses >25%% vs this recorded BENCH json "
                         "(a file, or a directory of BENCH_*.json merged)")
    ap.add_argument("--factor", type=float, default=REGRESSION_FACTOR,
                    help="regression threshold for --check as a ratio "
                         "(default %(default)s = the 25%% gate; CI lanes "
                         "on noisy shared runners pass a looser value)")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="enable jax's persistent compilation cache rooted "
                         "here (the CI bench lane persists this directory "
                         "across runs; see benchmarks/compile_cache.py)")
    args = ap.parse_args()
    if args.compile_cache:
        compile_cache.enable(args.compile_cache)
    if args.only and args.only not in {t for t, _ in MODULES}:
        # a typo'd tag must fail loudly, not run zero modules and let a
        # --check gate pass green on an empty measurement set
        ap.error(f"unknown module tag {args.only!r}; "
                 f"choose from {[t for t, _ in MODULES]}")

    # snapshot the baseline BEFORE any module runs: --json-dir merge-updates
    # the same BENCH files a --check baseline typically points at
    baseline = None
    if args.check:
        if os.path.isdir(args.check):
            paths = sorted(glob.glob(
                os.path.join(args.check, "BENCH_*.json")))
            if not paths:
                # an empty baseline dir must fail loudly, not let the
                # gate pass green with zero rows checked
                ap.error(f"--check {args.check!r}: no BENCH_*.json found")
            baseline = {}
            for p in paths:
                with open(p) as f:
                    baseline.update(json.load(f))
        else:
            with open(args.check) as f:
                baseline = json.load(f)

    measured: dict[str, tuple[float, str]] = {}
    tag_rows: list[tuple[str, list]] = []
    print("name,us_per_call,derived")
    for tag, mod in MODULES:
        if args.only and tag != args.only:
            continue
        rows = _module_rows(mod, args.smoke)
        tag_rows.append((tag, rows))
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}", flush=True)
            measured[name] = (us, derived)

    # gate BEFORE persisting: a failed check must not ratchet the recorded
    # baseline with the regressed numbers (the retry would then pass)
    if args.check and _check_regressions(args.check, baseline, measured,
                                         args.factor):
        sys.exit(1)

    if args.json_dir:
        os.makedirs(args.json_dir, exist_ok=True)
        for tag, rows in tag_rows:
            merge_bench_json(
                os.path.join(args.json_dir, f"BENCH_{tag}.json"), rows
            )

    path = os.path.join(os.path.dirname(__file__), "..",
                        "results", "dryrun_single.json")
    if os.path.exists(path) and not args.only and not args.smoke:
        with open(path) as f:
            recs = json.load(f)
        ok = [r for r in recs if r.get("ok")]
        print(f"# dry-run roofline summary ({len(ok)} combos):")
        for r in ok:
            t = r["roofline"]
            print(
                f"roofline_{r['arch']}_{r['shape']},"
                f"{t['bound_step_time_s']*1e6:.1f},"
                f"dom={t['dominant']};useful={t['useful_flop_ratio']:.2f}"
            )

if __name__ == "__main__":
    main()
