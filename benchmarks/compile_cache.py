"""Persistent-compilation-cache switch + warm-vs-cold compile benchmark.

Compile time is pure overhead the perf loop pays on every cold process —
for the big sweep programs it dwarfs the first measured steady-state call
(the N=1e6 edge-sharded program spends minutes in XLA before the first
step runs). jax ships an on-disk executable cache
(``jax_compilation_cache_dir``); :func:`enable` turns it on for the whole
harness (``benchmarks/run.py --compile-cache DIR``) and the CI bench lane
persists that directory across workflow runs, so re-benchmarking an
unchanged program costs a deserialization, not a compile.

:func:`rows` pins the claim with two rows over the same lowered program:

* ``compile_sweep_cold`` — first ``.compile()`` in this process. A real
  XLA compile when the on-disk cache is empty (``cache=miss``), a disk
  read when a previous run populated it (``cache=hit``) — which one
  happened is detected by whether the compile wrote a new cache entry and
  recorded in the derived tag.
* ``compile_sweep_warm`` — ``jax.clear_caches()`` then recompile: with
  the persistent cache on this is always disk-served, so warm << cold on
  any first (miss) run is the cache working end-to-end.

Both rows are tagged ``gate=off``: compiler wall time jitters far beyond
the perf gate's budget and measures XLA + disk, not the engines.
"""
import glob
import os
import time

import jax
import numpy as np

from repro.core.graphs import random_strongly_connected_edge_list
from repro.core.pushsum import run_pushsum_sparse


def enable(cache_dir: str) -> None:
    """Turn on jax's persistent compilation cache rooted at ``cache_dir``.

    The min-compile-time / min-entry-size floors are dropped to zero so
    the CI smoke programs (which compile in well under a second) are
    cached too — the lane's whole point. Flags that this jax build lacks
    are skipped silently rather than gating the harness on a version.
    """
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    for flag, val in (
        ("jax_persistent_cache_min_compile_time_secs", 0.0),
        ("jax_persistent_cache_min_entry_size_bytes", -1),
    ):
        try:
            jax.config.update(flag, val)
        except AttributeError:
            pass


def _cache_dir() -> str | None:
    return getattr(jax.config, "jax_compilation_cache_dir", None)


def _cache_entries(cache_dir: str | None) -> int:
    if not cache_dir or not os.path.isdir(cache_dir):
        return 0
    return len(glob.glob(os.path.join(cache_dir, "**"), recursive=True))


def rows(smoke: bool = False):
    n, d, T = (256, 2, 20) if smoke else (512, 4, 50)
    rng = np.random.default_rng(0)
    el = random_strongly_connected_edge_list(n, 2.0, rng)
    w = rng.normal(size=(n, d)).astype(np.float32)

    fn = jax.jit(lambda w_, s_, d_: run_pushsum_sparse(
        w_, s_, d_, T, drop_prob=0.2, B=4, record_every=T)[1])

    cache_dir = _cache_dir()
    before = _cache_entries(cache_dir)
    lowered = fn.lower(w, el.src, el.dst)
    t0 = time.perf_counter()
    lowered.compile()
    cold_s = time.perf_counter() - t0
    if cache_dir is None:
        cache = "off"
    elif _cache_entries(cache_dir) > before:
        cache = "miss"            # a real compile wrote a new entry
    elif before > 1:
        cache = "hit"             # served from a pre-populated cache
    else:
        # empty dir and nothing written: the cache is configured but not
        # taking entries (enable() called after backend init, or the jax
        # build ignores the min-compile-time floor) — say so instead of
        # mislabeling it a hit
        cache = "uncached"

    # drop the in-memory executable so the second compile must go back to
    # the persistent layer (or recompile, when the cache is off)
    jax.clear_caches()
    lowered = fn.lower(w, el.src, el.dst)
    t0 = time.perf_counter()
    lowered.compile()
    warm_s = time.perf_counter() - t0

    warm_cache = ("off" if cache_dir is None
                  else "hit" if _cache_entries(cache_dir) > 1
                  else "uncached")
    base = f"N={n};d={d};T={T};gate=off"
    return [
        ("compile_sweep_cold", cold_s * 1e6, f"{base};cache={cache}"),
        ("compile_sweep_warm", warm_s * 1e6,
         f"{base};cache={warm_cache};"
         f"speedup_vs_cold={cold_s / max(warm_s, 1e-9):.1f}x"),
    ]
