"""Theorem 1 benchmark: HPS consensus-error decay vs B, Gamma, M.

Paper claims validated:
 * error decays exponentially (gamma^(t/2Gamma));
 * smaller B (more reliable links) => faster;
 * more sub-networks (smaller D*) => faster than one gigantic network
   (Remark 2).
Emits name,us_per_call,derived rows; derived = final consensus error.
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core.graphs import make_hierarchy
from repro.core.hps import HPSConfig, run_hps


def _run(sizes, gamma, B, drop, T=600, seed=0, topology="complete"):
    topo = make_hierarchy(sizes, topology=topology, seed=seed)
    w = np.random.default_rng(seed).normal(size=(topo.N, 4)).astype(np.float32)
    cfg = HPSConfig(topo=topo, gamma_period=gamma, B=B, drop_prob=drop)
    t0 = time.perf_counter()
    _, traj = run_hps(jnp.asarray(w), cfg, T, seed=seed)
    traj = np.asarray(traj)
    wall = (time.perf_counter() - t0) / T * 1e6
    err = np.abs(traj - w.mean(0)).max(axis=(1, 2))
    return wall, err


def rows():
    out = []
    # B sweep (drop forced-delivery window) under heavy loss
    for B in (1, 2, 8):
        wall, err = _run([6, 6, 6], gamma=8, B=B, drop=0.7)
        out.append((f"thm1_consensus_B{B}", wall,
                    f"err_t300={err[300]:.2e}"))
    # M sweep at fixed N=24 on RINGS: hierarchy shrinks the diameter D*
    # (Remark 2) — one 24-ring (D=23) vs four 6-rings (D=5) + PS fusion
    for sizes in ([24], [12, 12], [6, 6, 6, 6]):
        wall, err = _run(sizes, gamma=4, B=2, drop=0.2, topology="ring",
                         T=900)
        out.append(
            (f"thm1_consensus_ringM{len(sizes)}", wall,
             f"err_t600={err[600]:.2e}")
        )
    # exponential decay checkpoints
    wall, err = _run([6, 6, 6], gamma=4, B=1, drop=0.1)
    halves = [float(err[t]) for t in (100, 200, 400)]
    out.append(("thm1_decay_checkpoints", wall,
                "err(100;200;400)=" + ";".join(f"{h:.1e}" for h in halves)))
    return out
