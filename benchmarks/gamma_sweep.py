"""Remark 3 benchmark: PS-fusion sparsity (Gamma) vs learning quality.

The paper's observation: "up to certain region, less frequent communication
does not lead to increase of training error" — while the global
communication cost drops linearly in 1/Gamma.
"""
import time

import numpy as np

from repro.core.graphs import make_hierarchy
from repro.core.hps import HPSConfig
from repro.core.signals import make_confused_model
from repro.core.social import run_social_learning


def rows():
    out = []
    topo = make_hierarchy([6, 6, 6], topology="complete", seed=4)
    model = make_confused_model(N=topo.N, m=3, truth=0, confusion=0.5, seed=2)
    T = 600
    for gamma in (2, 8, 32, 128):
        cfg = HPSConfig(topo=topo, gamma_period=gamma, B=2, drop_prob=0.2)
        t0 = time.perf_counter()
        res = run_social_learning(model, cfg, T=T, seed=1)
        wall = (time.perf_counter() - t0) / T * 1e6
        b = np.asarray(res.beliefs[-1])
        n_fusions = T // gamma
        out.append((f"remark3_gamma{gamma}", wall,
                    f"final_min={b[:,0].min():.3f};ps_msgs={n_fusions}"))
    return out
